"""One benchmark per paper figure/table (DESIGN.md §7 index).

Each ``fig*`` function regenerates its paper artifact from the calibrated
transport simulator and returns rows of ``{name, value, paper, unit}`` so
`run.py` can emit the consolidated CSV and EXPERIMENTS.md can cite exact
model-vs-paper numbers.
"""

from __future__ import annotations

import math

from repro.core.signaling import ScheduleKind, Transfer, build_schedule
from repro.core.transport_sim import (
    A100, H100, IBGDA, IBRC, LIBFABRIC, NVLINK,
    DEEPSEEK_V3, GPT_OSS_120B, LLAMA4_SCOUT, QWEN3_30B,
    fit_alpha_beta, nccl_alltoall_latency, signaling_efficiency,
    simulate_alltoall, simulate_forward, simulate_moe_layer, simulate_proxy,
)

MODELS = {"qwen3": QWEN3_30B, "gptoss": GPT_OSS_120B, "dsv3": DEEPSEEK_V3,
          "llama4": LLAMA4_SCOUT}


def _fwd(spec, s, n, tp, sched, gpu=A100, ppn=4, **kw):
    return simulate_forward(
        spec, tokens_per_pe=s, n_nodes=n, pe_per_node=ppn, transport=tp,
        gpu=gpu, schedule=sched, **kw,
    )


def _row(name, value, paper=None, unit=""):
    return {"name": name, "value": round(float(value), 4),
            "paper": paper, "unit": unit}


# --------------------------------------------------------------------------


def fig1_weak_scaling() -> list[dict]:
    """Fig. 1 (top): weak scaling, per-GPU workload fixed (S=1024)."""
    rows = []
    for key, spec in (("qwen3", QWEN3_30B), ("gptoss", GPT_OSS_120B),
                      ("llama4", LLAMA4_SCOUT)):
        base = _fwd(spec, 1024, 1, NVLINK, "coupled")
        for n in (2, 4, 8):
            if spec is LLAMA4_SCOUT and n * 4 > 16:
                continue  # 16 experts cap EP at 16 GPUs (paper note)
            deg = _fwd(spec, 1024, n, LIBFABRIC, "coupled") / base
            paper = {("qwen3", 8): 10.0, ("gptoss", 8): None,
                     ("llama4", 4): 1.3}.get((key, n))
            rows.append(_row(f"fig1/{key}/deg_{n}n", deg, paper, "x"))
    return rows


def fig5_signaling() -> list[dict]:
    """Fig. 5: signaling efficiency + aggregate fence time."""
    rows = []
    for n in (2, 4, 8):
        eff = signaling_efficiency(n_transfers=96, nbytes=4096, n_nodes=n,
                                   params=LIBFABRIC, kind="coupled")
        paper = {8: 0.02}.get(n)
        rows.append(_row(f"fig5a/eff_96x4KB_{n}n", eff, paper, "frac"))
    anchors = {(2, 4096): 0.96, (8, 4096): 6.1, (2, 1 << 20): 3.5,
               (8, 1 << 20): 9.2}
    for (n, nb), paper in anchors.items():
        tr = [Transfer(i, 1 + (i % ((n - 1) * 4)), nb, 1 + (i % (n - 1)))
              for i in range(96)]
        base = simulate_proxy(build_schedule(tr, "put_only"), LIBFABRIC,
                              n_nodes=n).total_time
        coup = simulate_proxy(build_schedule(tr, "coupled"), LIBFABRIC,
                              n_nodes=n).total_time
        kb = nb // 1024
        rows.append(_row(f"fig5b/fence_ms_{n}n_{kb}KB",
                         (coup - base) / 1e3, paper, "ms"))
    tr = [Transfer(i, 1 + (i % 28), 4096, 1 + (i % 7)) for i in range(96)]
    r = simulate_proxy(build_schedule(tr, "coupled"), LIBFABRIC, n_nodes=8)
    rows.append(_row("fig5c/fence_share_4KB_8n",
                     r.proxy_stall / r.total_time, 0.98, "frac"))
    return rows


def fig7_group_size() -> list[dict]:
    """Fig. 7: decoupled-signaling group-size sweep (S=1K, 8 nodes)."""
    rows = []
    coup = simulate_moe_layer(
        QWEN3_30B, tokens_per_pe=1024, n_nodes=8, pe_per_node=4,
        transport=LIBFABRIC, schedule="coupled",
    )
    rows.append(_row("fig7/coupled_ms", coup.latency_us / 1e3, 22.7, "ms"))
    for gs, paper in ((1, 19.9), (4, None), (28, 12.3), (112, None)):
        r = simulate_moe_layer(
            QWEN3_30B, tokens_per_pe=1024, n_nodes=8, pe_per_node=4,
            transport=LIBFABRIC, schedule="decoupled", group_size=gs,
        )
        rows.append(_row(f"fig7/decoupled_g{gs}_ms", r.latency_us / 1e3,
                         paper, "ms"))
        rows.append(_row(f"fig7/fences_g{gs}", r.dispatch.n_fences,
                         {1: 112, 28: 4}.get(gs), ""))
    return rows


def fig8_combined() -> list[dict]:
    """Fig. 8: decoupling x NIC ordering across group sizes, S=1K/64K."""
    rows = []
    for s in (1024, 65536):
        van = simulate_moe_layer(
            QWEN3_30B, tokens_per_pe=s, n_nodes=4, pe_per_node=4,
            transport=LIBFABRIC, schedule="coupled",
        ).latency_us
        for gs in (1, 8, 96):
            r = simulate_moe_layer(
                QWEN3_30B, tokens_per_pe=s, n_nodes=4, pe_per_node=4,
                transport=LIBFABRIC, schedule="perseus", group_size=gs,
            ).latency_us
            rows.append(_row(f"fig8/S{s}_g{gs}_speedup", van / r, None, "x"))
    return rows


def fig9_e2e() -> list[dict]:
    """Fig. 9: end-to-end speedups per transport/model/S/nodes."""
    rows = []
    best = 0.0
    for s in (256, 1024, 4096, 16384):
        for n in (2, 4, 8, 16):
            sp = (_fwd(QWEN3_30B, s, n, LIBFABRIC, "coupled")
                  / _fwd(QWEN3_30B, s, n, LIBFABRIC, "perseus"))
            best = max(best, sp)
            if s in (1024,) or n in (8,):
                rows.append(_row(f"fig9/LF_qwen3_S{s}_{n}n", sp, None, "x"))
    rows.append(_row("fig9/LF_qwen3_peak", best, 10.3, "x"))
    for key, spec, paper in (("gptoss", GPT_OSS_120B, 2.8),
                             ("dsv3", DEEPSEEK_V3, 2.2)):
        peak = max(
            _fwd(spec, s, 8, LIBFABRIC, "coupled")
            / _fwd(spec, s, 8, LIBFABRIC, "perseus")
            for s in (1024, 4096, 16384)
        )
        rows.append(_row(f"fig9/LF_{key}_peak8n", peak, paper, "x"))
    sp64 = (_fwd(QWEN3_30B, 65536, 4, IBRC, "coupled", H100, 8)
            / _fwd(QWEN3_30B, 65536, 4, IBRC, "perseus", H100, 8))
    rows.append(_row("fig9/IBRC_qwen3_S64K_4n", sp64, 2.47, "x"))
    for s in (1024, 65536):
        ratio = (_fwd(QWEN3_30B, s, 4, IBGDA, "coupled", H100, 8)
                 / _fwd(QWEN3_30B, s, 4, IBRC, "perseus", H100, 8))
        rows.append(_row(f"fig9/IBGDAvan_over_IBRCperseus_S{s}", ratio,
                         1.2 if s == 65536 else None, "x"))
    return rows


def fig10_ablation() -> list[dict]:
    """Fig. 10: decoupled-only vs NIC-only vs Perseus, 2 and 8 nodes."""
    rows = []
    paper = {("decoupled", 2): (1.2, 1.5), ("nic_ordered", 2): (1.1, 1.4),
             ("decoupled", 8): (1.2, 1.6), ("nic_ordered", 8): (1.3, 2.6),
             ("perseus", 8): (1.5, 3.5)}
    for n in (2, 8):
        van = _fwd(QWEN3_30B, 1024, n, LIBFABRIC, "coupled")
        for kind in ("decoupled", "nic_ordered", "perseus"):
            sp = van / _fwd(QWEN3_30B, 1024, n, LIBFABRIC, kind)
            p = paper.get((kind, n))
            rows.append(_row(f"fig10/{kind}_{n}n", sp,
                             None if p is None else sum(p) / 2, "x"))
    return rows


def fig11_triton_alltoall() -> list[dict]:
    """Fig. 11: communication-only ALLTOALL, overhead (alpha) elimination."""
    rows = []
    for n, nb, paper_cut in ((4, 1 << 22, 0.99),):
        v = simulate_alltoall(n_nodes=n, pe_per_node=4, nbytes_per_peer=nb,
                              transport=LIBFABRIC, schedule="coupled")
        p = simulate_alltoall(n_nodes=n, pe_per_node=4, nbytes_per_peer=nb,
                              transport=LIBFABRIC, schedule="perseus")
        a_v = v.total_time - v.wire_busy
        a_p = p.total_time - p.wire_busy
        rows.append(_row(f"fig11/alpha_cut_{n}n", 1 - a_p / a_v, paper_cut,
                         "frac"))
        rows.append(_row(f"fig11/speedup_{n}n", v.total_time / p.total_time,
                         None, "x"))
    sp_small = []
    for nb in (2048, 8192):
        v = simulate_alltoall(n_nodes=4, pe_per_node=4, nbytes_per_peer=nb,
                              transport=LIBFABRIC, schedule="coupled")
        p = simulate_alltoall(n_nodes=4, pe_per_node=4, nbytes_per_peer=nb,
                              transport=LIBFABRIC, schedule="perseus")
        sp_small.append(v.total_time / p.total_time)
    rows.append(_row("fig11/peak_speedup_small", max(sp_small), 79.0, "x"))
    return rows


def fig12_skew() -> list[dict]:
    """Fig. 12: robustness to Zipf-skewed routing."""
    rows = []
    for z in (0.0, 0.5, 1.0, 1.5):
        sp = (_fwd(QWEN3_30B, 1024, 8, LIBFABRIC, "coupled", skew_zipf=z)
              / _fwd(QWEN3_30B, 1024, 8, LIBFABRIC, "perseus", skew_zipf=z))
        paper = {0.0: 2.7, 1.5: 2.0}.get(z)
        rows.append(_row(f"fig12/S1K_zipf{z}_8n", sp, paper, "x"))
    return rows


def fig13_nccl() -> list[dict]:
    """Fig. 13: GPU-initiated ALLTOALL vs NCCL collective."""
    rows = []
    for nb, tagged in ((4096, "small"), (1 << 22, "large")):
        v = simulate_alltoall(n_nodes=4, pe_per_node=4, nbytes_per_peer=nb,
                              transport=LIBFABRIC, schedule="coupled")
        p = simulate_alltoall(n_nodes=4, pe_per_node=4, nbytes_per_peer=nb,
                              transport=LIBFABRIC, schedule="perseus")
        nccl = nccl_alltoall_latency(n_nodes=4, pe_per_node=4,
                                     nbytes_per_peer=nb,
                                     transport=LIBFABRIC)
        rows.append(_row(f"fig13/vanilla_over_nccl_{tagged}",
                         v.total_time / nccl, 18.7 if tagged == "small"
                         else None, "x"))
        rows.append(_row(f"fig13/nccl_over_perseus_{tagged}",
                         nccl / p.total_time, 11.0 if tagged == "small"
                         else None, "x"))
    return rows


def fig14_recovery() -> list[dict]:
    """Fig. 14: microbenchmark + weak-scaling recovery."""
    rows = []
    e_v = signaling_efficiency(n_transfers=96, nbytes=4096, n_nodes=8,
                               params=LIBFABRIC, kind="coupled")
    e_p = signaling_efficiency(n_transfers=96, nbytes=4096, n_nodes=8,
                               params=LIBFABRIC, kind="perseus")
    rows.append(_row("fig14/eff_vanilla", e_v, 0.02, "frac"))
    rows.append(_row("fig14/eff_perseus", e_p, 0.74, "frac"))
    base = _fwd(QWEN3_30B, 1024, 1, NVLINK, "coupled")
    rows.append(_row("fig14/deg16_vanilla",
                     _fwd(QWEN3_30B, 1024, 16, LIBFABRIC, "coupled") / base,
                     19.0, "x"))
    rows.append(_row("fig14/deg16_perseus",
                     _fwd(QWEN3_30B, 1024, 16, LIBFABRIC, "perseus") / base,
                     3.5, "x"))
    gbase = _fwd(GPT_OSS_120B, 1024, 1, NVLINK, "coupled")
    rows.append(_row("fig14/gptoss_deg16_perseus",
                     _fwd(GPT_OSS_120B, 1024, 16, LIBFABRIC, "perseus")
                     / gbase, None, "x"))
    return rows


def table2_utilization() -> list[dict]:
    """Table 2: TensorCore utilization at 4 nodes, normalized to 1 node."""
    rows = []
    paper = {"qwen3": (0.31, 0.95), "gptoss": (0.75, 0.98)}
    for key, spec in (("qwen3", QWEN3_30B), ("gptoss", GPT_OSS_120B)):
        sn = simulate_moe_layer(spec, tokens_per_pe=1024, n_nodes=1,
                                pe_per_node=4, transport=NVLINK,
                                schedule="coupled")
        u1 = sn.compute_busy_us / (
            _fwd(spec, 1024, 1, NVLINK, "coupled") / spec.n_moe_layers)
        for sched, idx in (("coupled", 0), ("perseus", 1)):
            l4 = simulate_moe_layer(spec, tokens_per_pe=1024, n_nodes=4,
                                    pe_per_node=4, transport=LIBFABRIC,
                                    schedule=sched)
            lat = _fwd(spec, 1024, 4, LIBFABRIC, sched) / spec.n_moe_layers
            rows.append(_row(f"table2/{key}_{sched}",
                             (l4.compute_busy_us / lat) / u1,
                             paper[key][idx], "frac"))
    return rows


def appendixA_alphabeta() -> list[dict]:
    """Appendix A: alpha-beta decomposition per transport."""
    rows = []

    def ab(transport, sched, nodes, ppn, gpu):
        sizes, lats = [], []
        for s in (1024, 4096, 16384, 65536):
            lats.append(_fwd(QWEN3_30B, s, nodes, transport, sched, gpu, ppn)
                        / QWEN3_30B.n_moe_layers)
            sizes.append(s * 256)
        return fit_alpha_beta(sizes, lats)

    av, bv, r2v = ab(LIBFABRIC, "coupled", 16, 4, A100)
    ap_, bp, r2p = ab(LIBFABRIC, "perseus", 16, 4, A100)
    rows.append(_row("appA/LF_alpha_vanilla_ms", av / 1e3, 22.28, "ms"))
    rows.append(_row("appA/LF_alpha_perseus_ms", ap_ / 1e3, 2.21, "ms"))
    rows.append(_row("appA/LF_alpha_cut", 1 - ap_ / av, 0.90, "frac"))
    rows.append(_row("appA/LF_r2", min(r2v, r2p), 0.99, ""))
    ai_v, bi_v, _ = ab(IBRC, "coupled", 4, 8, H100)
    ai_p, bi_p, _ = ab(IBRC, "perseus", 4, 8, H100)
    rows.append(_row("appA/IBRC_beta_cut", 1 - bi_p / bi_v, 0.60, "frac"))
    return rows


def fusedAB_overlap() -> list[dict]:
    """Staged vs fused megakernel A/B: tile-granular overlap (this repo's
    ``backend="fused"`` vs the staged dispatch->FFN->combine path, both
    under the Perseus issue discipline).  ``staged`` inserts the dispatch
    kernel's all-recv barrier and a global pre-combine barrier; ``fused``
    starts each tile's GEMMs on its own signal and releases each combine
    PUT as its tile retires.  No paper anchor: this measures the repo's
    own beyond-paper fusion, at a decode-size batch and at S=1K."""
    rows = []
    for s, tag in ((16, "decode16"), (1024, "S1K")):
        kw = dict(tokens_per_pe=s, n_nodes=4, pe_per_node=4,
                  transport=LIBFABRIC, schedule="perseus")
        staged = simulate_moe_layer(QWEN3_30B, fused=False, **kw)
        fus = simulate_moe_layer(QWEN3_30B, fused=True, **kw)
        last_sig = max(fus.dispatch.signal_visible.values())
        rows.append(_row(f"fusedAB/{tag}_staged_latency_us",
                         staged.latency_us, None, "us"))
        rows.append(_row(f"fusedAB/{tag}_fused_latency_us",
                         fus.latency_us, None, "us"))
        rows.append(_row(f"fusedAB/{tag}_speedup",
                         staged.latency_us / fus.latency_us, None, "x"))
        rows.append(_row(f"fusedAB/{tag}_staged_util",
                         staged.utilization, None, "frac"))
        rows.append(_row(f"fusedAB/{tag}_fused_util",
                         fus.utilization, None, "frac"))
        # The no-all-recv-barrier witness: first expert tile starts compute
        # strictly before the last dispatch signal becomes visible.
        rows.append(_row(f"fusedAB/{tag}_first_compute_us",
                         fus.first_compute_us, None, "us"))
        rows.append(_row(f"fusedAB/{tag}_last_signal_us",
                         last_sig, None, "us"))
        rows.append(_row(
            f"fusedAB/{tag}_overlap_demonstrated",
            1.0 if fus.first_compute_us < last_sig else 0.0, None, "bool",
        ))
    return rows


ALL_FIGURES = {
    "fig1": fig1_weak_scaling,
    "fig5": fig5_signaling,
    "fig7": fig7_group_size,
    "fig8": fig8_combined,
    "fig9": fig9_e2e,
    "fig10": fig10_ablation,
    "fig11": fig11_triton_alltoall,
    "fig12": fig12_skew,
    "fig13": fig13_nccl,
    "fig14": fig14_recovery,
    "table2": table2_utilization,
    "appendixA": appendixA_alphabeta,
    "fusedAB": fusedAB_overlap,
}
