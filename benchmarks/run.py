"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (scaffold contract): ``us_per_call``
carries each benchmark's primary value, ``derived`` carries the paper's
reference number (empty when the paper has no anchor) plus the unit.

``--json BENCH_<name>.json`` additionally writes the rows as a
machine-readable perf artifact (the repo's perf trajectory), always
including the staged-vs-fused A/B rows (``fusedAB``) so later PRs can
track overlap regressions.

Usage::

    PYTHONPATH=src python -m benchmarks.run              # everything
    PYTHONPATH=src python -m benchmarks.run --only fig9  # one figure
    PYTHONPATH=src python -m benchmarks.run --roofline   # dry-run report
    PYTHONPATH=src python -m benchmarks.run --only fusedAB \
        --json BENCH_fused_ab.json
"""

from __future__ import annotations

import argparse
import json
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated figure keys (fig1..fusedAB)")
    ap.add_argument("--roofline", action="store_true",
                    help="print the dry-run roofline table and exit")
    ap.add_argument("--skip-wallclock", action="store_true")
    ap.add_argument("--json", default=None, metavar="BENCH_<name>.json",
                    help="also write rows as a JSON perf artifact")
    args = ap.parse_args(argv)

    from benchmarks import figures, kernel_bench, roofline_report

    if args.roofline:
        print(roofline_report.report())
        return

    rows: list[dict] = []
    keys = (args.only.split(",") if args.only
            else list(figures.ALL_FIGURES))
    for key in keys:
        fn = figures.ALL_FIGURES[key]
        print(f"# {key}: {fn.__doc__.splitlines()[0]}", file=sys.stderr)
        rows.extend(fn())
    if not args.only and not args.skip_wallclock:
        rows.extend(kernel_bench.run())
        try:
            rows.extend(roofline_report.csv_rows())
        except Exception as e:  # dry-run artifacts may not exist yet
            print(f"# roofline skipped: {e!r}", file=sys.stderr)

    if args.json:
        # The A/B rows are the artifact's reason to exist: make sure they
        # are present even when --only selected a different figure subset.
        if not any(r["name"].startswith("fusedAB/") for r in rows):
            rows.extend(figures.ALL_FIGURES["fusedAB"]())
        with open(args.json, "w") as f:
            json.dump({"schema": "bench-rows/v1", "rows": rows}, f, indent=2)
            f.write("\n")
        print(f"# wrote {args.json} ({len(rows)} rows)", file=sys.stderr)

    print("name,us_per_call,derived")
    for r in rows:
        paper = "" if r["paper"] is None else r["paper"]
        derived = f"paper={paper};unit={r['unit']}"
        print(f"{r['name']},{r['value']},{derived}")


if __name__ == "__main__":
    main()
