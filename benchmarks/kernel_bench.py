"""Wall-clock microbenchmarks of the JAX substrate on this host.

CPU wall-time is NOT the graded roofline (that comes from the dry-run);
these timings exist to catch regressions in the pure-JAX paths and to give
the ``us_per_call`` column the benchmark CSV promises.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> list[dict]:
    rows = []
    rng = np.random.RandomState(0)

    # gathered MoE block (single device)
    from repro.core.moe import MoEConfig, init_moe, moe_apply
    cfg = MoEConfig(d_model=128, d_ff=256, n_experts=16, top_k=2,
                    dtype=jnp.float32)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.randn(512, 128), jnp.float32)
    f = jax.jit(lambda p, x: moe_apply(p, cfg, x, backend="gathered"))
    rows.append({"name": "kernel/moe_gathered_512tok",
                 "value": round(_time(f, params, x), 1),
                 "paper": None, "unit": "us_per_call"})

    # flash attention vs xla attention (correct + timing)
    from repro.kernels import ops, ref
    q = jnp.asarray(rng.randn(1, 4, 256, 64), jnp.float32)
    k = jnp.asarray(rng.randn(1, 2, 256, 64), jnp.float32)
    v = jnp.asarray(rng.randn(1, 2, 256, 64), jnp.float32)
    fx = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v))
    rows.append({"name": "kernel/attn_xla_256",
                 "value": round(_time(fx, q, k, v), 1),
                 "paper": None, "unit": "us_per_call"})

    # SSD chunked scan (jnp path used by the models)
    from repro.configs.base import ArchConfig, LayerSpec
    from repro.models import layers as L
    acfg = ArchConfig(name="b", family="ssm", n_layers=1, d_model=128,
                      n_heads=2, n_kv_heads=2, d_ff=0, vocab=64,
                      ssm_state=16, ssm_head_dim=32,
                      pattern=(LayerSpec(mixer="ssd", ffn="none"),),
                      dtype="float32")
    p = L.init_ssd(jax.random.PRNGKey(0), acfg)
    u = jnp.asarray(rng.randn(2, 512, 128), jnp.float32) * 0.3
    fs = jax.jit(lambda p, u: L.ssd_fwd(p, acfg, u))
    rows.append({"name": "kernel/ssd_jnp_512",
                 "value": round(_time(fs, p, u), 1),
                 "paper": None, "unit": "us_per_call"})

    # transport simulator throughput (events/s — it drives every figure)
    from repro.core.signaling import Transfer, build_schedule
    from repro.core.transport_sim import LIBFABRIC, simulate_proxy
    tr = [Transfer(i, 1 + i % 28, 65536, 1 + i % 7) for i in range(112)]
    sched = build_schedule(tr, "perseus")
    t0 = time.perf_counter()
    for _ in range(50):
        simulate_proxy(sched, LIBFABRIC, n_nodes=8)
    rows.append({"name": "kernel/sim_dispatch_112tr",
                 "value": round((time.perf_counter() - t0) / 50 * 1e6, 1),
                 "paper": None, "unit": "us_per_call"})
    return rows
