"""Roofline analysis from the dry-run artifacts (DESIGN.md §8).

Per (arch x shape x mesh) cell:

    compute term    = HLO_FLOPs_global   / (chips * 197e12 bf16 FLOP/s)
    memory term     = HLO_bytes_global   / (chips * 819e9  B/s HBM)
    collective term = wire_bytes_per_dev / (links_per_chip * 50e9 B/s ICI)

FLOPs/bytes come from the loop-corrected two-point extrapolation recorded
by ``repro.launch.dryrun`` (cost_analysis counts while bodies once);
collective bytes are parsed from the optimized HLO.  The dominant term is
the bottleneck the §Perf hillclimb attacks.  MODEL_FLOPS = 6*N*D (dense) or
6*N_active*D (MoE) with D = trained/prefilled tokens (decode: batch tokens);
the ratio MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy waste.
"""

from __future__ import annotations

import glob
import json
import os

# TPU v5e-class hardware constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
ICI_LINKS = 2                # usable links per chip on a 2D torus axis avg

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "dryrun")


def model_flops(rec: dict) -> float:
    """6*N(active)*D for the cell's step (train: fwd+bwd = 3x2ND -> 6ND;
    prefill: 2ND; decode: 2N*B_new_tokens)."""
    n = rec["active_params"]
    if rec["kind"] == "train":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 6.0 * n * tokens
    if rec["kind"] == "prefill":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 2.0 * n * tokens
    tokens = rec["global_batch"]          # one new token per sequence
    return 2.0 * n * tokens


def load_cells(results_dir: str = RESULTS_DIR, tag: str = "") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("tag", "") != tag:
            continue
        cells.append(rec)
    return cells


def roofline_terms(rec: dict) -> dict | None:
    if rec.get("status") != "OK":
        return None
    chips = rec.get("n_devices", 256)
    ex = rec.get("extrapolated", {})
    flops_dev = ex.get("flops") or rec.get("cost", {}).get("flops", 0.0)
    bytes_dev = ex.get("bytes_accessed") or rec.get("cost", {}).get(
        "bytes_accessed", 0.0)
    wire_dev = ex.get("wire_bytes_per_device")
    if wire_dev is None:
        wire_dev = rec.get("collectives", {}).get(
            "wire_bytes_per_device", 0.0)
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = wire_dev / (ICI_BW * ICI_LINKS)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    hlo_global = flops_dev * chips
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "bound_s": max(terms.values()),
        "roofline_fraction": (
            compute_s / max(terms.values()) if max(terms.values()) else 0.0
        ),
    }


_SUGGEST = {
    "compute": "compute-bound: raise MFU (fused kernels, larger tiles); "
               "reduce remat recompute if useful_ratio is low",
    "memory": "HBM-bound: fuse elementwise chains, cast activations to "
              "bf16, shrink optimizer/cache traffic",
    "collective": "collective-bound: reshard to cut all-gathers, overlap "
                  "dispatch with expert compute (Perseus schedule), "
                  "reduce-scatter instead of all-reduce",
}


def report(results_dir: str = RESULTS_DIR, tag: str = "") -> str:
    lines = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s "
        "| dominant | MODEL/HLO | roofline_frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    rows = []
    for rec in load_cells(results_dir, tag):
        key = f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
        if rec["status"] == "SKIP":
            lines.append(key + "| — | — | — | SKIP | — | — |")
            continue
        if rec["status"] != "OK":
            lines.append(key + "| — | — | — | FAIL | — | — |")
            continue
        t = roofline_terms(rec)
        rows.append((rec, t))
        lines.append(
            key + f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | {t['dominant']} "
            f"| {t['useful_ratio']:.2f} | {t['roofline_fraction']:.2f} |"
        )
    return "\n".join(lines)


def csv_rows(results_dir: str = RESULTS_DIR, tag: str = "") -> list[dict]:
    out = []
    for rec in load_cells(results_dir, tag):
        if rec["status"] != "OK":
            out.append({"name": f"roofline/{rec['arch']}/{rec['shape']}/"
                        f"{rec['mesh']}", "value": -1.0,
                        "paper": None, "unit": rec["status"]})
            continue
        t = roofline_terms(rec)
        out.append({
            "name": f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}",
            "value": round(t["roofline_fraction"], 4),
            "paper": None,
            "unit": f"dom={t['dominant']}",
        })
    return out
