"""Multi-device parallel features (subprocess: fake devices must be set
before jax import): pipeline parallelism, compressed gradient psum."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(code: str, timeout=900):
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout,
    )
    return r


@pytest.mark.slow
def test_pipeline_matches_sequential():
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, {SRC!r})
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.parallel.pipeline import pipeline_apply

        n_stages, n_micro, mb, d = 4, 8, 2, 16
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (n_stages, d, d)) * 0.3

        def stage(w, x):
            return jnp.tanh(x @ w)

        x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))
        # sequential reference
        ref = x
        for i in range(n_stages):
            ref = jax.vmap(lambda xx: stage(ws[i], xx))(ref)
        mesh = Mesh(np.array(jax.devices()), ("pod",))
        out = pipeline_apply(stage, ws, x, mesh=mesh, axis="pod")
        err = float(jnp.abs(out - ref).max())
        assert err < 1e-5, err
        print("PIPELINE_OK")
    """)
    r = _run(code)
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr[-3000:]


@pytest.mark.slow
def test_compressed_psum_multidevice():
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, {SRC!r})
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro import compat
        from repro.parallel.compression import compressed_psum

        mesh = Mesh(np.array(jax.devices()), ("pod",))
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 256))

        def body(xs):
            return compressed_psum(xs[0], "pod")

        out = jax.jit(compat.shard_map(
            body, mesh=mesh, in_specs=P("pod"), out_specs=P()))(x)
        exact = np.asarray(x.sum(0))
        got = np.asarray(out)
        scale = np.abs(x).max() / 127.0
        # error bounded by n_ranks * half-step of the shared grid
        assert np.abs(got - exact).max() <= 4 * scale, (
            np.abs(got - exact).max(), scale)
        print("PSUM_OK")
    """)
    r = _run(code)
    assert "PSUM_OK" in r.stdout, r.stdout + r.stderr[-3000:]


@pytest.mark.slow
def test_moe_collective_multipod_axes():
    """EP dispatch under the multi-pod axis layout: tokens sharded over
    (pod, data, model), all_to_all over model only."""
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, {SRC!r})
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.core.moe import MoEConfig, init_moe, moe_apply

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = MoEConfig(d_model=16, d_ff=32, n_experts=4, top_k=2,
                        dtype=jnp.float32, capacity_factor=8.0,
                        token_axes=("pod", "data", "model"))
        params = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
        dense = moe_apply(params, cfg, x, backend="dense")
        with compat.use_mesh(mesh):
            got = jax.jit(lambda p, x: moe_apply(
                p, cfg, x, backend="collective", mesh=mesh))(params, x)
        err = float(jnp.abs(got - dense).max())
        assert err < 1e-4, err
        print("MULTIPOD_OK")
    """)
    r = _run(code)
    assert "MULTIPOD_OK" in r.stdout, r.stdout + r.stderr[-3000:]
