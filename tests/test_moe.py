"""MoE block: routing invariants + backend agreement (incl. the Pallas
megakernel dispatch under shard_map, run in a multi-device subprocess)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.core.moe import MoEConfig, init_moe, moe_apply
from repro.core.routing import expert_capacity, topk_routing, zipf_gate_bias


def _cfg(**kw):
    d = dict(d_model=32, d_ff=64, n_experts=8, top_k=2, dtype=jnp.float32,
             capacity_factor=8.0)
    d.update(kw)
    return MoEConfig(**d)


# --------------------------------------------------------------------------
# routing
# --------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(
    t=st.integers(1, 64),
    e=st.sampled_from([4, 8, 16]),
    k=st.integers(1, 4),
    cf=st.floats(0.25, 4.0),
)
def test_routing_invariants(t, e, k, cf):
    k = min(k, e)
    key = jax.random.PRNGKey(t * 131 + e)
    logits = jax.random.normal(key, (t, e))
    cap = expert_capacity(t, e, k, cf)
    info = topk_routing(logits, k, cap)
    # each kept slot's position is unique within its expert
    flat = np.asarray(info.expert_idx * cap + info.position).reshape(-1)
    keep = np.asarray(info.keep).reshape(-1)
    kept = flat[keep]
    assert len(set(kept.tolist())) == len(kept), "position collision"
    assert np.all(np.asarray(info.position)[np.asarray(info.keep)] < cap)
    # weights normalized over selected slots
    w = np.asarray(info.weight)
    assert np.all(w >= 0)
    assert_allclose(w.sum(-1), 1.0, rtol=1e-5)
    # capacity respected: per-expert kept count <= cap
    counts = np.bincount(
        np.asarray(info.expert_idx).reshape(-1)[keep], minlength=e
    )
    assert counts.max() <= cap


def test_routing_deterministic_token_order():
    logits = jax.random.normal(jax.random.PRNGKey(0), (32, 8))
    a = topk_routing(logits, 2, 16)
    b = topk_routing(logits, 2, 16)
    assert np.array_equal(np.asarray(a.position), np.asarray(b.position))


def test_zipf_bias_shapes_traffic():
    bias = zipf_gate_bias(128, 1.5)
    assert bias.shape == (128,)
    assert bias[0] > bias[-1]
    assert abs(float(np.asarray(zipf_gate_bias(128, 0.0)).sum())) == 0.0


# --------------------------------------------------------------------------
# single-device backends
# --------------------------------------------------------------------------


def test_gathered_matches_dense():
    cfg = _cfg()
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    d = moe_apply(params, cfg, x, backend="dense")
    g = moe_apply(params, cfg, x, backend="gathered")
    assert_allclose(np.asarray(d), np.asarray(g), rtol=1e-5, atol=1e-5)


def test_capacity_drops_are_consistent():
    """With a tight capacity factor both backends drop the same tokens."""
    cfg = _cfg(capacity_factor=0.5)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    d = moe_apply(params, cfg, x, backend="dense")
    g = moe_apply(params, cfg, x, backend="gathered")
    assert_allclose(np.asarray(d), np.asarray(g), rtol=1e-5, atol=1e-5)
    # and some tokens actually get partially dropped vs full capacity
    full = moe_apply(params, _cfg(), x, backend="dense")
    assert not np.allclose(np.asarray(d), np.asarray(full))


def test_moe_grads_flow():
    cfg = _cfg()
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))

    def loss(p):
        return jnp.sum(moe_apply(p, cfg, x, backend="gathered") ** 2)

    g = jax.grad(loss)(params)
    norms = {k: float(jnp.linalg.norm(v)) for k, v in g.items()}
    assert all(np.isfinite(list(norms.values())))
    assert norms["w1"] > 0 and norms["w_gate"] > 0


# --------------------------------------------------------------------------
# multi-device backends (subprocess: needs fake devices before jax import)
# --------------------------------------------------------------------------

_MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro import compat
    from repro.core.moe import MoEConfig, init_moe, moe_apply

    cfg = MoEConfig(d_model=32, d_ff=64, n_experts=8, top_k=2,
                    dtype=jnp.float32, capacity_factor=8.0,
                    token_axes=("model",))
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    dense = moe_apply(params, cfg, x, backend="dense")
    mesh = Mesh(np.array(jax.devices()), ("model",))
    with compat.use_mesh(mesh):
        coll = jax.jit(lambda p, x: moe_apply(
            p, cfg, x, backend="collective", mesh=mesh))(params, x)
        mk = jax.jit(lambda p, x: moe_apply(
            p, cfg, x, backend="megakernel", mesh=mesh))(params, x)
        fus = jax.jit(lambda p, x: moe_apply(
            p, cfg, x, backend="fused", mesh=mesh))(params, x)
        rep = jax.jit(lambda p, x: moe_apply(
            p, cfg, x, backend="replicated", mesh=mesh))(params, x)
    for name, got in [("collective", coll), ("megakernel", mk),
                      ("fused", fus), ("replicated", rep)]:
        err = float(jnp.abs(got - dense).max())
        assert err < 1e-4, (name, err)

    # Pallas dispatch kernels address peers by flat logical device id:
    # a multi-axis mesh must be refused, not silently corrupted.
    mesh2 = jax.make_mesh((2, 2), ("data", "model"))
    cfg2 = MoEConfig(d_model=32, d_ff=64, n_experts=8, top_k=2,
                     dtype=jnp.float32, capacity_factor=8.0,
                     token_axes=("data", "model"))
    for be in ("megakernel", "fused"):
        try:
            moe_apply(params, cfg2, x, backend=be, mesh=mesh2)
            raise AssertionError(f"{{be}}: multi-axis mesh not refused")
        except NotImplementedError:
            pass
    print("MULTIDEV_OK")
""")

_DISPATCH_SWEEP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro import compat
    from repro.kernels.moe_dispatch import remote_dispatch
    from repro.kernels.ref import dispatch_ref

    devs = np.array(jax.devices())
    rng = np.random.RandomState(0)
    # (n_ranks, e_local, capacity, hidden) x dtype x schedule sweep
    cases = [
        (2, 1, 4, 8, np.float32, "coupled"),
        (4, 3, 8, 16, np.float32, "decoupled"),
        (8, 2, 4, 32, np.float32, "perseus"),
        (4, 2, 16, 24, np.float32, "nic_ordered"),   # non-128 hidden
    ]
    for P_, E_, C, H, dt, sched in cases:
        mesh = Mesh(devs[:P_], ("model",))
        g = rng.randn(P_ * P_, E_, C, H).astype(dt)
        f = compat.shard_map(
            functools.partial(remote_dispatch, axis_name="model",
                              schedule=sched),
            mesh=mesh, in_specs=P("model"), out_specs=P("model"))
        got = np.asarray(jax.jit(f)(jnp.asarray(g)))
        exp = np.asarray(dispatch_ref(jnp.asarray(g), P_))
        assert np.allclose(got, exp), (P_, E_, C, H, dt, sched)
    # bf16 payloads
    mesh = Mesh(devs[:4], ("model",))
    g = jnp.asarray(rng.randn(16, 2, 8, 16), jnp.bfloat16)
    f = compat.shard_map(
        functools.partial(remote_dispatch, axis_name="model",
                          schedule="perseus"),
        mesh=mesh, in_specs=P("model"), out_specs=P("model"))
    got = jax.jit(f)(g)
    exp = dispatch_ref(g, 4)
    assert jnp.array_equal(got, exp)   # pure data movement: bit-exact
    print("DISPATCH_SWEEP_OK")
""")

_FUSED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.core.moe import MoEConfig, init_moe, moe_apply

    mesh = Mesh(np.array(jax.devices()), ("model",))

    def check(cfg, T, tol):
        params = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (T, cfg.d_model))
        dense = moe_apply(params, cfg, x, backend="dense")
        fused = jax.jit(lambda p, x: moe_apply(
            p, cfg, x, backend="fused", mesh=mesh))(params, x)
        err = float(jnp.abs(fused.astype(jnp.float32)
                            - dense.astype(jnp.float32)).max())
        assert err < tol, (cfg.schedule, cfg.n_experts, T, err)

    # all four signaling schedules at a prefill-size batch (E=8, k=2)
    for sched in ("coupled", "decoupled", "nic_ordered", "perseus"):
        check(MoEConfig(d_model=32, d_ff=64, n_experts=8, top_k=2,
                        dtype=jnp.float32, capacity_factor=8.0,
                        token_axes=("model",), schedule=sched), 64, 1e-4)
    # decode-size batch: one token per rank (E=16, k=4), all schedules
    for sched in ("coupled", "decoupled", "nic_ordered", "perseus"):
        check(MoEConfig(d_model=16, d_ff=32, n_experts=16, top_k=4,
                        dtype=jnp.float32, capacity_factor=4.0,
                        token_axes=("model",), schedule=sched), 4, 1e-4)
    # bf16 payloads within bf16 tolerance
    check(MoEConfig(d_model=32, d_ff=64, n_experts=8, top_k=2,
                    dtype=jnp.bfloat16, capacity_factor=8.0,
                    token_axes=("model",), schedule="perseus"), 64, 5e-2)
    print("FUSED_SWEEP_OK")
""")


@pytest.mark.slow
def test_remote_dispatch_shape_dtype_sweep():
    """Per-kernel sweep for the remote-DMA dispatch megakernel: rank
    counts x tile shapes x schedules x dtypes against the pure-jnp oracle
    (data movement must be bit-exact)."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", _DISPATCH_SWEEP_SCRIPT.format(
            src=os.path.abspath(src))],
        capture_output=True, text=True, timeout=900,
    )
    assert "DISPATCH_SWEEP_OK" in r.stdout, r.stdout + r.stderr[-3000:]


@pytest.mark.slow
def test_ep_backends_match_dense_multidevice():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT.format(
            src=os.path.abspath(src))],
        capture_output=True, text=True, timeout=900,
    )
    assert "MULTIDEV_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_fused_backend_matches_dense_multidevice():
    """Acceptance sweep for backend="fused": all four signaling schedules
    x {prefill-size (E=8,P=4,k=2), decode-size (E=16,P=4,k=4, one token
    per rank)} against the dense oracle, plus a bf16 case, on a CPU mesh
    in interpret mode."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", _FUSED_SCRIPT.format(
            src=os.path.abspath(src))],
        capture_output=True, text=True, timeout=900,
    )
    assert "FUSED_SWEEP_OK" in r.stdout, r.stdout + r.stderr[-3000:]


def test_fused_backend_single_rank():
    """In-process smoke: on a 1-rank mesh the fused kernel reduces to the
    local DMA + per-expert FFN path and must still match the oracle."""
    from jax.sharding import Mesh

    cfg = _cfg(token_axes=("model",))
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
    dense = moe_apply(params, cfg, x, backend="dense")
    mesh = Mesh(np.array(jax.devices()[:1]), ("model",))
    fused = jax.jit(
        lambda p, x: moe_apply(p, cfg, x, backend="fused", mesh=mesh)
    )(params, x)
    assert_allclose(np.asarray(fused), np.asarray(dense),
                    rtol=1e-4, atol=1e-4)
