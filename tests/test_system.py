"""End-to-end behaviour tests for the whole system.

These tie the layers together: paper mechanism -> MoE workload -> training
runtime -> launch tooling, the way a deploying team would smoke-test the
framework.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_paper_pipeline_end_to_end():
    """The core story in one test: vanilla signaling collapses, Perseus
    recovers it, and the resulting e2e speedup is in the paper's regime."""
    from repro.core.signaling import build_schedule, moe_dispatch_transfers
    from repro.core.transport_sim import (
        LIBFABRIC, QWEN3_30B, simulate_forward, simulate_proxy,
    )

    transfers = moe_dispatch_transfers(
        my_pe=0, n_pe=16, pe_per_node=4, n_experts=128,
        bytes_per_expert=32768,
    )
    assert len(transfers) == 96                      # §3.2 running example
    v = simulate_proxy(build_schedule(transfers, "coupled"), LIBFABRIC,
                       n_nodes=4)
    p = simulate_proxy(build_schedule(transfers, "perseus"), LIBFABRIC,
                       n_nodes=4)
    assert p.total_time < v.total_time / 2
    assert p.n_fences == 12 and v.n_fences == 96     # 8x fence reduction
    sp = (simulate_forward(QWEN3_30B, tokens_per_pe=1024, n_nodes=4,
                           pe_per_node=4, transport=LIBFABRIC,
                           schedule="coupled")
          / simulate_forward(QWEN3_30B, tokens_per_pe=1024, n_nodes=4,
                             pe_per_node=4, transport=LIBFABRIC,
                             schedule="perseus"))
    assert sp > 2.0


def test_train_launcher_cli(tmp_path):
    """The training launcher runs end to end from the CLI."""
    hist = tmp_path / "hist.json"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "tinyllama-1.1b", "--smoke", "--steps", "8",
         "--batch", "4", "--seq", "32",
         "--ckpt-dir", str(tmp_path / "ckpt"),
         "--history-out", str(hist)],
        env={**os.environ, "PYTHONPATH": SRC},
        capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    data = json.loads(hist.read_text())
    assert len(data) == 8
    assert all(np.isfinite(h["loss"]) for h in data)


def test_serve_launcher_cli():
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve",
         "--arch", "mamba2-780m", "--smoke", "--requests", "3",
         "--max-new", "4", "--slots", "2", "--max-len", "48"],
        env={**os.environ, "PYTHONPATH": SRC},
        capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "3 requests" in r.stdout


def test_dryrun_single_cell_subprocess(tmp_path):
    """Deliverable (e) smoke: lower+compile one full-size cell on the
    16x16 production mesh inside a fresh process (512 fake devices)."""
    out_dir = tmp_path / "dry"
    code = textwrap.dedent(f"""
        import sys
        sys.argv = ["dryrun"]
        sys.path.insert(0, {SRC!r})
        from repro.launch import dryrun
        rec = dryrun.run_cell(
            "tinyllama-1.1b", "train_4k", "single",
            out_dir={str(out_dir)!r}, force=True,
        )
        assert rec["status"] == "OK", rec
        assert rec["cost"]["flops"] > 0
        assert rec["collectives"]["wire_bytes_per_device"] > 0
        assert rec["extrapolated"]["flops"] > rec["cost"]["flops"]
        print("DRYRUN_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=1200)
    assert "DRYRUN_OK" in r.stdout, r.stdout[-1500:] + r.stderr[-3000:]


def test_collective_parser():
    from repro.launch.dryrun import parse_collectives

    hlo = """
  %ar = f32[1024,512]{1,0} all-reduce(f32[1024,512]{1,0} %x), replica_groups=[16,16]<=[256], to_apply=%sum
  %ag.1 = bf16[4096]{0} all-gather(bf16[256]{0} %y), replica_groups={{0,1,2,3}}, dimensions={0}
  %rs = f32[64]{0} reduce-scatter(f32[1024]{0} %z), replica_groups=[2,8]<=[16], to_apply=%sum
  %a2a = f32[32,8]{1,0} all-to-all(f32[32,8]{1,0} %w), replica_groups=[16,16]<=[256]
  %cp = u8[128]{0} collective-permute(u8[128]{0} %v), source_target_pairs={{0,1}}
"""
    res = parse_collectives(hlo)
    assert res["by_kind_count"] == {
        "all-reduce": 1, "all-gather": 1, "reduce-scatter": 1,
        "all-to-all": 1, "collective-permute": 1,
    }
    ar = 2 * 1024 * 512 * 4 * (15 / 16)
    assert abs(res["by_kind_bytes"]["all-reduce"] - ar) < 1
    ag = 4096 * 2 * (3 / 4)
    assert abs(res["by_kind_bytes"]["all-gather"] - ag) < 1
    rs = 1024 * 4 * (7 / 8)
    assert abs(res["by_kind_bytes"]["reduce-scatter"] - rs) < 1
    assert res["by_kind_bytes"]["collective-permute"] == 128


def test_roofline_terms_math():
    from benchmarks.roofline_report import model_flops, roofline_terms

    rec = {
        "status": "OK", "n_devices": 256, "kind": "train",
        "global_batch": 256, "seq_len": 4096,
        "active_params": int(1e9),
        "extrapolated": {"flops": 4e13, "bytes_accessed": 1e12,
                         "wire_bytes_per_device": 1e10},
        "cost": {}, "collectives": {},
    }
    t = roofline_terms(rec)
    assert abs(t["compute_s"] - 4e13 / 197e12) < 1e-9
    assert abs(t["memory_s"] - 1e12 / 819e9) < 1e-9
    assert t["dominant"] in ("compute", "memory", "collective")
    assert abs(model_flops(rec) - 6 * 1e9 * 256 * 4096) < 1
    assert 0 < t["useful_ratio"] < 2


def test_elastic_checkpoint_reshard(tmp_path):
    """Elastic scaling: save under one (virtual) mesh, restore under a
    different sharding layout — global shapes are mesh-independent."""
    from repro.checkpoint.manager import CheckpointManager

    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(3, tree)
    # restore with an explicit (single-device) sharding object
    shard = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    restored, _ = mgr.restore(tree, shardings={"w": shard})
    assert np.array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))


def test_compressed_psum_single_device():
    """int8 EF compression is exact for values on the int8 grid and
    bounded-error otherwise (single-axis shard_map over 1 device)."""
    from repro.parallel.compression import dequantize_int8, quantize_int8

    x = jnp.asarray(np.linspace(-3, 3, 1000), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x)).max()
    assert err <= float(s) / 2 + 1e-6
