"""§Perf optimization levers must preserve semantics.

Every hillclimb change (EXPERIMENTS.md §Perf) is an equivalence-preserving
rewrite; these tests pin that: chunked attention == dense attention,
chunked loss == plain loss, quantized optimizer still optimizes, bf16
params train stably.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.configs.base import reduce_for_smoke
from repro.configs.registry import get_config
from repro.models.registry import build_model
from repro.optim.adamw import OptConfig, apply_updates, init_opt

KEY = jax.random.PRNGKey(0)


def _setup(arch="tinyllama-1.1b", **cfg_over):
    cfg = reduce_for_smoke(get_config(arch))
    if cfg_over:
        cfg = dataclasses.replace(cfg, **cfg_over)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                     cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0,
                                     cfg.vocab),
    }
    return cfg, model, params, batch


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_chunked_attention_equivalent(chunk):
    _, m0, params, batch = _setup()
    base = float(m0.loss(params, batch))
    _, m1, _, _ = _setup(attn_chunk=chunk)
    assert abs(float(m1.loss(params, batch)) - base) < 1e-4


@pytest.mark.parametrize("arch", ["gemma3-27b", "recurrentgemma-2b"])
def test_chunked_attention_with_windows(arch):
    """Sliding-window layers must respect the window inside chunks too."""
    _, m0, params, batch = _setup(arch)
    base = float(m0.loss(params, batch))
    _, m1, _, _ = _setup(arch, attn_chunk=8)
    assert abs(float(m1.loss(params, batch)) - base) < 1e-4


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_chunked_loss_equivalent(chunk):
    _, m0, params, batch = _setup()
    base = float(m0.loss(params, batch))
    _, m1, _, _ = _setup(loss_chunk=chunk)
    assert abs(float(m1.loss(params, batch)) - base) < 1e-4


def test_chunked_loss_gradients_match():
    cfg0, m0, params, batch = _setup()
    _, m1, _, _ = _setup(loss_chunk=16)
    g0 = jax.grad(m0.loss)(params, batch)
    g1 = jax.grad(m1.loss)(params, batch)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-4)


def test_quantized_opt_state_trains():
    _, model, params, batch = _setup()
    state = init_opt(params, quantize=True)
    oc = OptConfig(lr=3e-3, warmup_steps=2, total_steps=30)
    losses = []
    for i in range(30):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, state, _ = apply_updates(params, grads, state, oc)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.2, losses[::6]
    # int8 payloads really are int8
    q_leaves = [x for x in jax.tree.leaves(state.mu)
                if x.dtype == jnp.int8]
    assert q_leaves, "no quantized moments found"


def test_quantized_opt_memory_footprint():
    """4 bytes/moment -> ~1.05 bytes/moment (the kimi HBM-fit lever)."""
    from repro.parallel.sharding import count_bytes
    params = {"w": jnp.zeros((1024, 512), jnp.float32)}
    full = init_opt(params)
    quant = init_opt(params, quantize=True)
    assert count_bytes(quant.mu) < 0.3 * count_bytes(full.mu)


def test_bf16_params_train_step():
    cfg, model, params, batch = _setup()
    params = jax.tree.map(
        lambda p: p.astype(jnp.bfloat16) if p.ndim >= 2 else p, params
    )
    state = init_opt(params)
    oc = OptConfig(lr=3e-3, warmup_steps=2, total_steps=20)
    l0 = None
    for i in range(20):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, state, _ = apply_updates(params, grads, state, oc)
        l0 = l0 or float(loss)
    assert np.isfinite(float(loss)) and float(loss) < l0
