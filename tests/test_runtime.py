"""Runtime: checkpoint manager, fault-tolerant trainer, straggler monitor,
serving loop, optimizer, data determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import LM_SHAPES, reduce_for_smoke
from repro.configs.registry import get_config
from repro.data.synthetic import SyntheticDataset
from repro.models.registry import build_model
from repro.optim.adamw import (
    OptConfig, apply_updates, cosine_schedule, init_opt,
)
from repro.runtime.serve_loop import Request, ServeConfig, Server
from repro.runtime.train_loop import (
    StragglerMonitor, TrainConfig, Trainer, make_train_step,
)


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = init_opt(params)
    cfg = OptConfig(lr=0.2, weight_decay=0.0, warmup_steps=1, total_steps=200)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_clip_and_schedule():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(cosine_schedule(cfg, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < lrs[9]                       # warmup rises
    assert lrs[20] > lrs[90]                     # cosine decays
    assert min(lrs) >= cfg.lr * cfg.min_lr_ratio * 0.5


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------


def test_data_deterministic_and_restartable():
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    shape = LM_SHAPES["train_4k"]
    ds1 = SyntheticDataset(cfg, shape, seed=7, batch_override=4,
                           seq_override=32)
    ds2 = SyntheticDataset(cfg, shape, seed=7, batch_override=4,
                           seq_override=32)
    b5a = ds1.batch(5)
    # simulate a restart: fresh object, same counter
    b5b = ds2.batch(5)
    assert np.array_equal(np.asarray(b5a["tokens"]), np.asarray(b5b["tokens"]))
    assert not np.array_equal(
        np.asarray(ds1.batch(6)["tokens"]), np.asarray(b5a["tokens"])
    )
    # labels are the shifted stream (next-token)
    assert np.array_equal(
        np.asarray(b5a["labels"])[:, :-1], np.asarray(b5a["tokens"])[:, 1:]
    )


# --------------------------------------------------------------------------
# checkpoint manager
# --------------------------------------------------------------------------


def _tree(x=1.0):
    return {"a": jnp.full((4, 3), x), "b": {"c": jnp.arange(5)}}


def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for step in (10, 20, 30):
        mgr.save(step, _tree(step))
    assert mgr.all_steps() == [20, 30]           # keep-2 GC
    assert mgr.latest_step() == 30
    restored, meta = mgr.restore(_tree())
    assert_allclose(np.asarray(restored["a"]), 30.0)


def test_checkpoint_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(1, _tree(1.0))
    # a crashed partial write must be ignored
    os.makedirs(tmp_path / "step_000000002.tmp")
    assert mgr.latest_step() == 1
    restored, _ = mgr.restore(_tree())
    assert_allclose(np.asarray(restored["a"]), 1.0)


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(5, _tree(5.0))
    mgr.wait()
    assert mgr.latest_step() == 5


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _tree())
    bad = {"a": jnp.zeros((2, 2)), "b": {"c": jnp.arange(5)}}
    with pytest.raises(ValueError):
        mgr.restore(bad)


# --------------------------------------------------------------------------
# trainer: loss goes down, faults recover, stragglers flagged
# --------------------------------------------------------------------------


def _tiny_setup(tmp_path, steps=40, ckpt_every=10):
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shape = LM_SHAPES["train_4k"]
    ds = SyntheticDataset(cfg, shape, seed=0, batch_override=8,
                          seq_override=32)
    step = make_train_step(
        model.loss, OptConfig(lr=3e-3, warmup_steps=4, total_steps=steps)
    )
    tc = TrainConfig(steps=steps, ckpt_every=ckpt_every,
                     ckpt_dir=str(tmp_path), log_every=0)
    return step, ds, params, tc


def test_training_loss_decreases(tmp_path):
    step, ds, params, tc = _tiny_setup(tmp_path)
    trainer = Trainer(step, ds, params, tc, log=lambda *_: None)
    hist = trainer.run()
    first = np.mean([h["loss"] for h in hist[:4]])
    last = np.mean([h["loss"] for h in hist[-4:]])
    assert last < first - 0.05, f"no learning: {first:.3f} -> {last:.3f}"


def test_fault_recovery_resumes_from_checkpoint(tmp_path):
    step, ds, params, tc = _tiny_setup(tmp_path, steps=20, ckpt_every=5)
    crashed = {"done": False}

    def fault(i):
        if i == 12 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected device loss")

    trainer = Trainer(step, ds, params, tc, fault_hook=fault,
                      log=lambda *_: None)
    hist = trainer.run()
    steps_seen = [h["step"] for h in hist]
    # step 12 failed once, was replayed after restore from step 10
    assert steps_seen.count(10) == 2 or steps_seen.count(11) == 2
    assert trainer.restarts == 1
    assert trainer.step_idx == 20


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(warmup=3, z_threshold=3.0)
    flagged = [mon.observe(i, 0.10 + 0.001 * (i % 3)) for i in range(20)]
    assert not any(flagged)
    assert mon.observe(20, 0.9)       # 9x normal step time
    assert mon.flagged and mon.flagged[0][0] == 20


# --------------------------------------------------------------------------
# serving loop
# --------------------------------------------------------------------------


def test_server_continuous_batching():
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    srv = Server(model, params, ServeConfig(slots=2, max_len=64))
    for rid in range(5):   # more requests than slots
        srv.submit(Request(rid=rid, prompt=[1 + rid, 2, 3],
                           max_new_tokens=4))
    done = srv.run_until_drained()
    assert len(done) == 5
    for req in done:
        assert len(req.out) >= 4
        assert all(0 <= t < cfg.vocab for t in req.out)
