"""Minimal deterministic stand-in for ``hypothesis`` when it's not installed.

The property tests in this suite only use a small slice of the hypothesis
API (``given``/``settings`` plus a handful of strategies).  When the real
package is available it is always preferred (see ``conftest.py``); this shim
exists so the tier-1 suite still *runs* the property tests — as seeded
random sweeps with a bounded example count — instead of erroring at
collection on an optional dependency.

Differences from real hypothesis (acceptable for a smoke fallback):
  * no shrinking, no example database, no health checks;
  * example count is capped at ``MAX_EXAMPLES_CAP`` regardless of
    ``settings(max_examples=...)``;
  * draws are seeded per-test-function (CRC32 of the name) so failures
    reproduce across runs.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

MAX_EXAMPLES_CAP = 25


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng: random.Random):
        return self._sample(rng)


def integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value, max_value):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def booleans():
    return _Strategy(lambda r: bool(r.getrandbits(1)))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda r: elements[r.randrange(len(elements))])


def one_of(*strategies):
    strategies = list(strategies)
    return _Strategy(lambda r: strategies[r.randrange(len(strategies))].sample(r))


def none():
    return _Strategy(lambda r: None)


def just(value):
    return _Strategy(lambda r: value)


def settings(max_examples: int = 20, **_ignored):
    """Decorator recording the example budget (deadline etc. are ignored)."""

    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(**strategies):
    """Keyword-only ``given``: runs the test over seeded random draws."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            budget = min(
                getattr(wrapper, "_shim_max_examples", 20), MAX_EXAMPLES_CAP
            )
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(budget):
                drawn = {k: s.sample(rng) for k, s in strategies.items()}
                fn(*args, **drawn, **kwargs)

        # Hide the drawn parameters from pytest's fixture resolution: the
        # wrapper itself takes no arguments beyond pass-through fixtures.
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        params = [
            p for name, p in inspect.signature(fn).parameters.items()
            if name not in strategies
        ]
        wrapper.__signature__ = inspect.Signature(params)
        return wrapper

    return deco


def install() -> None:
    """Register the shim as ``hypothesis`` / ``hypothesis.strategies``."""
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "one_of",
                 "none", "just"):
        setattr(st, name, globals()[name])
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.__is_shim__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
