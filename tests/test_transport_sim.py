"""Simulator invariants + mechanism properties (not paper-number bands —
those live in test_paper_claims.py)."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.signaling import ScheduleKind, Transfer, build_schedule
from repro.core.transport_sim import (
    IBGDA, IBRC, LIBFABRIC, NVLINK, A100, QWEN3_30B, GPT_OSS_120B,
    fit_alpha_beta, signaling_efficiency, simulate_moe_layer, simulate_proxy,
)


def _transfers(n, nbytes, n_dest=12):
    return [
        Transfer(tag=i, dest_pe=1 + (i % n_dest), nbytes=nbytes,
                 dest_node=1 + (i % 3))
        for i in range(n)
    ]


@pytest.mark.parametrize("params", [LIBFABRIC, IBRC, IBGDA, NVLINK])
@pytest.mark.parametrize("kind", ["coupled", "decoupled", "nic_ordered",
                                  "perseus", "put_only"])
def test_causality(params, kind):
    """Signals become visible only after their data arrived — the
    put-with-signal contract, for every transport and schedule."""
    tr = _transfers(24, 65536)
    res = simulate_proxy(build_schedule(tr, kind), params, n_nodes=4)
    for t in tr:
        assert res.data_arrival[t.tag] <= res.signal_visible[t.tag] + 1e-9, (
            f"{params.name}/{kind}: tag {t.tag} signal before data"
        )


def test_schedule_ordering_on_proxy():
    """On a proxy transport: perseus <= decoupled <= coupled total time."""
    tr = _transfers(96, 262144)
    times = {}
    for kind in ("coupled", "decoupled", "perseus", "put_only"):
        times[kind] = simulate_proxy(
            build_schedule(tr, kind), LIBFABRIC, n_nodes=8
        ).total_time
    assert times["put_only"] <= times["perseus"] <= times["decoupled"] \
        <= times["coupled"]


def test_fence_cost_grows_with_nodes():
    tr = _transfers(96, 4096)
    stalls = [
        simulate_proxy(build_schedule(tr, "coupled"), LIBFABRIC,
                       n_nodes=n).proxy_stall
        for n in (2, 4, 8)
    ]
    assert stalls[0] < stalls[1] < stalls[2]


def test_nic_ordering_never_blocks_proxy():
    tr = _transfers(64, 16384)
    res = simulate_proxy(build_schedule(tr, "nic_ordered"), LIBFABRIC,
                         n_nodes=8)
    assert res.proxy_stall == 0.0
    assert res.nic_stall > 0.0
    resp = simulate_proxy(build_schedule(tr, "perseus"), LIBFABRIC,
                          n_nodes=8)
    assert resp.proxy_stall == 0.0
    # perseus: only one flagged signal per destination group
    assert resp.n_fences == len({t.dest_pe for t in tr})


def test_ibgda_free_of_fence_cost():
    """GPU-direct in-QP ordering: coupled == perseus (no software fences)."""
    tr = _transfers(96, 65536)
    c = simulate_proxy(build_schedule(tr, "coupled"), IBGDA, n_nodes=4)
    p = simulate_proxy(build_schedule(tr, "perseus"), IBGDA, n_nodes=4)
    assert c.proxy_stall == 0.0
    assert abs(c.total_time - p.total_time) / c.total_time < 0.05


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(1, 128),
    nbytes=st.sampled_from([4096, 65536, 1 << 20]),
    nodes=st.integers(2, 16),
)
def test_efficiency_bounded(n, nbytes, nodes):
    for kind in ("coupled", "perseus"):
        eff = signaling_efficiency(
            n_transfers=n, nbytes=nbytes, n_nodes=nodes,
            params=LIBFABRIC, kind=kind,
        )
        assert 0.0 < eff <= 1.0 + 1e-9


@settings(max_examples=30, deadline=None)
@given(nodes=st.integers(2, 8), s=st.sampled_from([256, 1024, 4096]))
def test_perseus_layer_never_slower(nodes, s):
    v = simulate_moe_layer(
        QWEN3_30B, tokens_per_pe=s, n_nodes=nodes, pe_per_node=4,
        transport=LIBFABRIC, schedule="coupled",
    )
    p = simulate_moe_layer(
        QWEN3_30B, tokens_per_pe=s, n_nodes=nodes, pe_per_node=4,
        transport=LIBFABRIC, schedule="perseus",
    )
    assert p.latency_us <= v.latency_us * 1.01
    assert p.utilization >= v.utilization * 0.99


def test_skew_conserves_tokens():
    """Zipf skew redistributes but conserves total routed tokens (±rounding)."""
    from repro.core.transport_sim import _expert_token_counts
    flat = _expert_token_counts(QWEN3_30B, 1024, 0.0, 16)
    skew = _expert_token_counts(QWEN3_30B, 1024, 1.5, 16)
    assert abs(sum(flat) - sum(skew)) / sum(flat) < 0.02
    assert max(skew) > 5 * max(flat)  # actually skewed


def test_put_only_signal_visible_equals_data_arrival():
    """Regression for the PUT-only fallback: a schedule with no signals
    announces tiles at payload arrival — signal_visible must mirror
    data_arrival exactly (same tags, same times)."""
    tr = _transfers(24, 32768)
    res = simulate_proxy(build_schedule(tr, "put_only"), LIBFABRIC,
                         n_nodes=4)
    assert set(res.signal_visible) == set(res.data_arrival)
    for tag, t_arr in res.data_arrival.items():
        assert res.signal_visible[tag] == t_arr


def test_unsignaled_put_not_announced_in_signaled_stream():
    """When the stream DOES carry signals, a PUT with no matching signal is
    never announced: it must not appear in signal_visible (previously both
    branches of the fallback aliased it to data arrival)."""
    from repro.core.signaling import Op, OpKind

    ops = [
        Op(OpKind.PUT, dest_pe=1, nbytes=4096, tag=0, dest_node=1),
        Op(OpKind.PUT, dest_pe=1, nbytes=4096, tag=1, dest_node=1),
        Op(OpKind.FENCE),
        Op(OpKind.SIGNAL, dest_pe=1, nbytes=0, tag=0, dest_node=1),
    ]
    res = simulate_proxy(ops, LIBFABRIC, n_nodes=2)
    assert set(res.signal_visible) == {0}
    assert set(res.data_arrival) == {0, 1}


# --------------------------------------------------------------------------
# staged vs fused megakernel (tile-granular overlap A/B)
# --------------------------------------------------------------------------


def _layer(fused, tokens=1024, sched="perseus", **kw):
    return simulate_moe_layer(
        QWEN3_30B, tokens_per_pe=tokens, n_nodes=4, pe_per_node=4,
        transport=LIBFABRIC, schedule=sched, fused=fused, **kw,
    )


def test_fused_removes_all_recv_barrier():
    """Fused: the first expert tile starts computing strictly before the
    last dispatch signal is visible.  Staged: nothing computes until every
    signal has landed (the dispatch kernel's all-recv drain)."""
    fus = _layer(fused=True)
    stg = _layer(fused=False)
    last_signal = max(fus.dispatch.signal_visible.values())
    assert fus.first_compute_us < last_signal
    assert stg.first_compute_us >= max(stg.dispatch.signal_visible.values())


@pytest.mark.parametrize("sched", ["coupled", "perseus"])
@pytest.mark.parametrize("tokens", [16, 256, 1024])
def test_fused_never_slower_than_staged(sched, tokens):
    fus = _layer(fused=True, tokens=tokens, sched=sched)
    stg = _layer(fused=False, tokens=tokens, sched=sched)
    assert fus.latency_us <= stg.latency_us * 1.001
    assert fus.utilization >= stg.utilization * 0.999


def test_staged_single_node_includes_local_arrivals():
    """Regression: with no remote transfers (1 node) the staged barrier is
    the local-DMA arrival time, not 0 — staged must not model compute
    starting before any tile exists, and fused must not lose to staged."""
    kw = dict(tokens_per_pe=64, n_nodes=1, pe_per_node=4,
              transport=LIBFABRIC, schedule="perseus")
    stg = simulate_moe_layer(QWEN3_30B, fused=False, **kw)
    fus = simulate_moe_layer(QWEN3_30B, fused=True, **kw)
    assert stg.first_compute_us > 0.0
    assert fus.latency_us <= stg.latency_us * 1.001


def test_combine_release_tracks_each_tiles_finish():
    """Regression: combine ready times must be keyed by the tile's own
    finish (jobs.sort() reorders the queue).  With skewed routing, tiles
    have unequal durations, so a wrong index mapping shifts the last
    combine release off the true last-retire time."""
    kw = dict(tokens_per_pe=1024, n_nodes=4, pe_per_node=4,
              transport=LIBFABRIC, schedule="perseus", skew_zipf=1.0)
    r = simulate_moe_layer(QWEN3_30B, fused=True, **kw)
    # every combine PUT departs at/after its tile's compute could possibly
    # have retired, and the layer is internally consistent
    first_ready = min(r.dispatch.signal_visible.values())
    for ev in r.combine.events:
        if ev.op.kind.name == "PUT":
            assert ev.submit_t >= first_ready
    assert r.latency_us >= r.compute_busy_us


def test_fused_utilization_gain_largest_at_decode():
    """The fusion lever is the decode regime: modeled utilization must
    improve vs staged at decode-size batches (acceptance criterion)."""
    fus = _layer(fused=True, tokens=16)
    stg = _layer(fused=False, tokens=16)
    assert fus.utilization > stg.utilization * 1.05
    assert fus.latency_us < stg.latency_us


def test_alpha_beta_fit_recovers_line():
    xs = [1e3, 1e4, 1e5, 1e6]
    ys = [5.0 + 2e-4 * x for x in xs]
    a, b, r2 = fit_alpha_beta(xs, ys)
    assert abs(a - 5.0) < 1e-6
    assert abs(b - 2e-4) < 1e-9
    assert r2 > 0.999999


def test_compute_comm_ratio_ordering():
    """Paper footnote 2: Qwen3 << GPT-OSS << Llama4 in TFLOPs/GB."""
    from repro.core.transport_sim import LLAMA4_SCOUT
    q = QWEN3_30B.compute_comm_ratio()
    g = GPT_OSS_120B.compute_comm_ratio()
    l4 = LLAMA4_SCOUT.compute_comm_ratio()
    assert q < g < l4
    assert 3.0 < g / q < 4.5      # paper: 17.3/4.6 = 3.76
    assert 9.0 < l4 / q < 12.0    # paper: 49.2/4.6 = 10.7
