"""Simulator invariants + mechanism properties (not paper-number bands —
those live in test_paper_claims.py)."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.signaling import ScheduleKind, Transfer, build_schedule
from repro.core.transport_sim import (
    IBGDA, IBRC, LIBFABRIC, NVLINK, A100, QWEN3_30B, GPT_OSS_120B,
    fit_alpha_beta, signaling_efficiency, simulate_moe_layer, simulate_proxy,
)


def _transfers(n, nbytes, n_dest=12):
    return [
        Transfer(tag=i, dest_pe=1 + (i % n_dest), nbytes=nbytes,
                 dest_node=1 + (i % 3))
        for i in range(n)
    ]


@pytest.mark.parametrize("params", [LIBFABRIC, IBRC, IBGDA, NVLINK])
@pytest.mark.parametrize("kind", ["coupled", "decoupled", "nic_ordered",
                                  "perseus", "put_only"])
def test_causality(params, kind):
    """Signals become visible only after their data arrived — the
    put-with-signal contract, for every transport and schedule."""
    tr = _transfers(24, 65536)
    res = simulate_proxy(build_schedule(tr, kind), params, n_nodes=4)
    for t in tr:
        assert res.data_arrival[t.tag] <= res.signal_visible[t.tag] + 1e-9, (
            f"{params.name}/{kind}: tag {t.tag} signal before data"
        )


def test_schedule_ordering_on_proxy():
    """On a proxy transport: perseus <= decoupled <= coupled total time."""
    tr = _transfers(96, 262144)
    times = {}
    for kind in ("coupled", "decoupled", "perseus", "put_only"):
        times[kind] = simulate_proxy(
            build_schedule(tr, kind), LIBFABRIC, n_nodes=8
        ).total_time
    assert times["put_only"] <= times["perseus"] <= times["decoupled"] \
        <= times["coupled"]


def test_fence_cost_grows_with_nodes():
    tr = _transfers(96, 4096)
    stalls = [
        simulate_proxy(build_schedule(tr, "coupled"), LIBFABRIC,
                       n_nodes=n).proxy_stall
        for n in (2, 4, 8)
    ]
    assert stalls[0] < stalls[1] < stalls[2]


def test_nic_ordering_never_blocks_proxy():
    tr = _transfers(64, 16384)
    res = simulate_proxy(build_schedule(tr, "nic_ordered"), LIBFABRIC,
                         n_nodes=8)
    assert res.proxy_stall == 0.0
    assert res.nic_stall > 0.0
    resp = simulate_proxy(build_schedule(tr, "perseus"), LIBFABRIC,
                          n_nodes=8)
    assert resp.proxy_stall == 0.0
    # perseus: only one flagged signal per destination group
    assert resp.n_fences == len({t.dest_pe for t in tr})


def test_ibgda_free_of_fence_cost():
    """GPU-direct in-QP ordering: coupled == perseus (no software fences)."""
    tr = _transfers(96, 65536)
    c = simulate_proxy(build_schedule(tr, "coupled"), IBGDA, n_nodes=4)
    p = simulate_proxy(build_schedule(tr, "perseus"), IBGDA, n_nodes=4)
    assert c.proxy_stall == 0.0
    assert abs(c.total_time - p.total_time) / c.total_time < 0.05


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(1, 128),
    nbytes=st.sampled_from([4096, 65536, 1 << 20]),
    nodes=st.integers(2, 16),
)
def test_efficiency_bounded(n, nbytes, nodes):
    for kind in ("coupled", "perseus"):
        eff = signaling_efficiency(
            n_transfers=n, nbytes=nbytes, n_nodes=nodes,
            params=LIBFABRIC, kind=kind,
        )
        assert 0.0 < eff <= 1.0 + 1e-9


@settings(max_examples=30, deadline=None)
@given(nodes=st.integers(2, 8), s=st.sampled_from([256, 1024, 4096]))
def test_perseus_layer_never_slower(nodes, s):
    v = simulate_moe_layer(
        QWEN3_30B, tokens_per_pe=s, n_nodes=nodes, pe_per_node=4,
        transport=LIBFABRIC, schedule="coupled",
    )
    p = simulate_moe_layer(
        QWEN3_30B, tokens_per_pe=s, n_nodes=nodes, pe_per_node=4,
        transport=LIBFABRIC, schedule="perseus",
    )
    assert p.latency_us <= v.latency_us * 1.01
    assert p.utilization >= v.utilization * 0.99


def test_skew_conserves_tokens():
    """Zipf skew redistributes but conserves total routed tokens (±rounding)."""
    from repro.core.transport_sim import _expert_token_counts
    flat = _expert_token_counts(QWEN3_30B, 1024, 0.0, 16)
    skew = _expert_token_counts(QWEN3_30B, 1024, 1.5, 16)
    assert abs(sum(flat) - sum(skew)) / sum(flat) < 0.02
    assert max(skew) > 5 * max(flat)  # actually skewed


def test_alpha_beta_fit_recovers_line():
    xs = [1e3, 1e4, 1e5, 1e6]
    ys = [5.0 + 2e-4 * x for x in xs]
    a, b, r2 = fit_alpha_beta(xs, ys)
    assert abs(a - 5.0) < 1e-6
    assert abs(b - 2e-4) < 1e-9
    assert r2 > 0.999999


def test_compute_comm_ratio_ordering():
    """Paper footnote 2: Qwen3 << GPT-OSS << Llama4 in TFLOPs/GB."""
    from repro.core.transport_sim import LLAMA4_SCOUT
    q = QWEN3_30B.compute_comm_ratio()
    g = GPT_OSS_120B.compute_comm_ratio()
    l4 = LLAMA4_SCOUT.compute_comm_ratio()
    assert q < g < l4
    assert 3.0 < g / q < 4.5      # paper: 17.3/4.6 = 3.76
    assert 9.0 < l4 / q < 12.0    # paper: 49.2/4.6 = 10.7
