"""Tolerance-banded checks of the paper's headline claims against the
calibrated simulator (one test per claim; EXPERIMENTS.md reports exact
model-vs-paper numbers from benchmarks/)."""

import pytest

from repro.core.signaling import ScheduleKind, Transfer, build_schedule
from repro.core.transport_sim import (
    A100, H100, IBGDA, IBRC, LIBFABRIC, NVLINK, QWEN3_30B, GPT_OSS_120B,
    DEEPSEEK_V3, nccl_alltoall_latency, signaling_efficiency,
    simulate_alltoall, simulate_forward, simulate_proxy,
)


def _coupled_fence_ms(n_nodes, nbytes, n=96):
    tr = [Transfer(i, 1 + (i % ((n_nodes - 1) * 4)), nbytes,
                   1 + (i % (n_nodes - 1))) for i in range(n)]
    base = simulate_proxy(build_schedule(tr, "put_only"), LIBFABRIC,
                          n_nodes=n_nodes).total_time
    coup = simulate_proxy(build_schedule(tr, "coupled"), LIBFABRIC,
                          n_nodes=n_nodes).total_time
    return (coup - base) / 1e3


def test_fig5a_throughput_collapse():
    """Claim: coupled put+signal falls to ~2% of put-only at 96 transfers
    across 8 nodes (4KB)."""
    eff = signaling_efficiency(n_transfers=96, nbytes=4096, n_nodes=8,
                               params=LIBFABRIC, kind="coupled")
    assert 0.01 <= eff <= 0.05


def test_fig5b_aggregate_fence_times():
    """Claim: aggregate fence time 0.96ms @2 nodes -> 6.1ms @8 (4KB);
    3.5ms -> 9.2ms (1MB).  Band: +/-40%."""
    assert 0.6 <= _coupled_fence_ms(2, 4096) <= 1.4
    assert 4.0 <= _coupled_fence_ms(8, 4096) <= 8.5
    assert 2.1 <= _coupled_fence_ms(2, 1 << 20) <= 5.6
    assert 5.5 <= _coupled_fence_ms(8, 1 << 20) <= 13.0


def test_fig5c_fence_share_of_total():
    """Claim: fence overhead up to 98% of communication time at small
    message sizes, >= 19% at 4MB."""
    tr = [Transfer(i, 1 + (i % 28), 4096, 1 + (i % 7)) for i in range(96)]
    r = simulate_proxy(build_schedule(tr, "coupled"), LIBFABRIC, n_nodes=8)
    assert r.proxy_stall / r.total_time >= 0.90
    tr4 = [Transfer(i, 1 + (i % 28), 4 << 20, 1 + (i % 7))
           for i in range(96)]
    r4 = simulate_proxy(build_schedule(tr4, "coupled"), LIBFABRIC, n_nodes=8)
    assert r4.proxy_stall / r4.total_time >= 0.19


def test_fig14_throughput_recovery():
    """Claim: Perseus recovers 96x4KB/8-node efficiency from 2% to ~74%,
    and matches put-only at large messages."""
    eff = signaling_efficiency(n_transfers=96, nbytes=4096, n_nodes=8,
                               params=LIBFABRIC, kind="perseus")
    assert eff >= 0.5
    eff_large = signaling_efficiency(n_transfers=96, nbytes=1 << 20,
                                     n_nodes=8, params=LIBFABRIC,
                                     kind="perseus")
    assert eff_large >= 0.9


def _fwd(spec, s, n, tp, sched, gpu=A100, ppn=4):
    return simulate_forward(spec, tokens_per_pe=s, n_nodes=n,
                            pe_per_node=ppn, transport=tp, gpu=gpu,
                            schedule=sched)


def test_fig14_weak_scaling_recovery():
    """Claim: 16-node weak-scaling degradation 19x vanilla -> 3.5x Perseus
    (Qwen3, S=1K)."""
    base = _fwd(QWEN3_30B, 1024, 1, NVLINK, "coupled")
    deg_v = _fwd(QWEN3_30B, 1024, 16, LIBFABRIC, "coupled") / base
    deg_p = _fwd(QWEN3_30B, 1024, 16, LIBFABRIC, "perseus") / base
    assert 12 <= deg_v <= 26
    assert 1.5 <= deg_p <= 5.5
    assert deg_v / deg_p > 4


def test_fig9_libfabric_peak_speedup():
    """Claim: up to 10.3x end-to-end on Libfabric (Qwen3).  The simulator
    peaks in the same regime (small S, many nodes).  At S>=1K the model
    lands in [6, 14]x; at S=256 it over-predicts (~24x) because the
    per-layer fixed-cost floor of the real megakernel is larger than
    modeled — recorded as a known delta in EXPERIMENTS.md."""
    best = max(
        _fwd(QWEN3_30B, s, n, LIBFABRIC, "coupled")
        / _fwd(QWEN3_30B, s, n, LIBFABRIC, "perseus")
        for s in (1024, 4096) for n in (4, 8, 16)
    )
    assert 6.0 <= best <= 14.0


def test_fig9_speedup_ordering_by_comm_boundedness():
    """Claim: speedup higher for communication-bound models
    (Qwen3 10.3x > GPT-OSS 2.8x > DeepSeek 2.2x at their peaks)."""
    def peak(spec):
        return max(
            _fwd(spec, s, 8, LIBFABRIC, "coupled")
            / _fwd(spec, s, 8, LIBFABRIC, "perseus")
            for s in (1024, 4096, 16384)
        )
    assert peak(QWEN3_30B) > peak(GPT_OSS_120B) > peak(DEEPSEEK_V3) > 1.0


def test_fig9_ibrc_speedup_grows_with_s():
    """Claim: on IBRC speedups grow with S, reaching ~2.47x at S=64K."""
    sp = [
        _fwd(QWEN3_30B, s, 4, IBRC, "coupled", H100, 8)
        / _fwd(QWEN3_30B, s, 4, IBRC, "perseus", H100, 8)
        for s in (1024, 16384, 65536)
    ]
    assert sp[-1] >= 1.8
    assert 1.7 <= sp[-1] <= 3.2


def test_fig9_ibrc_perseus_matches_ibgda():
    """Claim: Perseus on IBRC matches or exceeds vanilla IBGDA (<=1.2x)."""
    for s in (1024, 65536):
        ratio = (_fwd(QWEN3_30B, s, 4, IBGDA, "coupled", H100, 8)
                 / _fwd(QWEN3_30B, s, 4, IBRC, "perseus", H100, 8))
        assert 0.85 <= ratio <= 2.0


def test_fig10_ablation_crossover():
    """Claim: decoupled-only beats NIC-only at 2 nodes; reversed at 8
    nodes; combined beats both everywhere."""
    def sp(kind, n):
        return (_fwd(QWEN3_30B, 1024, n, LIBFABRIC, "coupled")
                / _fwd(QWEN3_30B, 1024, n, LIBFABRIC, kind))
    # combined >= each component
    for n in (2, 8):
        assert sp("perseus", n) >= sp("decoupled", n) * 0.99
        assert sp("perseus", n) >= sp("nic_ordered", n) * 0.99
    # NIC-side ordering gains more at higher node counts
    assert sp("nic_ordered", 8) / sp("decoupled", 8) > \
        sp("nic_ordered", 2) / sp("decoupled", 2)


def test_fig11_triton_alltoall():
    """Claim: NIC-side ordering removes ~99% of serialization overhead in
    a communication-only ALLTOALL; speedups are 10x+ at small payloads."""
    v = simulate_alltoall(n_nodes=4, pe_per_node=4, nbytes_per_peer=16384,
                          transport=LIBFABRIC, schedule="coupled")
    p = simulate_alltoall(n_nodes=4, pe_per_node=4, nbytes_per_peer=16384,
                          transport=LIBFABRIC, schedule="perseus")
    assert v.proxy_stall > 0
    assert p.proxy_stall == 0
    overhead_cut = 1 - (p.total_time - p.wire_busy) / max(
        v.total_time - v.wire_busy, 1e-9)
    assert overhead_cut > 0.9
    assert v.total_time / p.total_time > 10


def test_fig13_nccl_comparison():
    """Claim: vanilla GPU-initiated ALLTOALL loses to NCCL; Perseus beats
    NCCL at small payloads (up to ~11x)."""
    for nbytes, perseus_wins in ((4096, True), (1 << 22, True)):
        v = simulate_alltoall(n_nodes=4, pe_per_node=4,
                              nbytes_per_peer=nbytes,
                              transport=LIBFABRIC, schedule="coupled")
        p = simulate_alltoall(n_nodes=4, pe_per_node=4,
                              nbytes_per_peer=nbytes,
                              transport=LIBFABRIC, schedule="perseus")
        nccl = nccl_alltoall_latency(n_nodes=4, pe_per_node=4,
                                     nbytes_per_peer=nbytes,
                                     transport=LIBFABRIC)
        assert v.total_time > nccl            # vanilla loses to NCCL
        if nbytes <= 16384:
            assert nccl / p.total_time > 3    # perseus well ahead at small S
                                              # (paper: up to 11x; model ~4x)


def test_fig12_skew_robustness():
    """Claim: speedup holds across Zipf skew 0 -> 1.5 (2-3x at 8 nodes)."""
    for z in (0.0, 0.5, 1.0, 1.5):
        s = (_fwd_skew(z, "coupled") / _fwd_skew(z, "perseus"))
        assert s > 1.5


def _fwd_skew(z, sched):
    return simulate_forward(
        QWEN3_30B, tokens_per_pe=1024, n_nodes=8, pe_per_node=4,
        transport=LIBFABRIC, schedule=sched, skew_zipf=z,
    )


def test_appendixA_alpha_beta():
    """Claim: Perseus cuts Libfabric alpha by ~90% at 16 nodes (Qwen3) and
    IBRC beta by up to ~60%; fits have R^2 > 0.99."""
    from repro.core.transport_sim import fit_alpha_beta

    def ab(transport, sched, nodes, ppn, gpu):
        sizes, lats = [], []
        for s in (1024, 4096, 16384, 65536):
            m = s * 256  # Qwen3: M = S*256 bytes (paper App. A)
            lats.append(simulate_forward(
                QWEN3_30B, tokens_per_pe=s, n_nodes=nodes, pe_per_node=ppn,
                transport=transport, gpu=gpu, schedule=sched,
            ) / QWEN3_30B.n_moe_layers)
            sizes.append(m)
        return fit_alpha_beta(sizes, lats)

    av, bv, r2v = ab(LIBFABRIC, "coupled", 16, 4, A100)
    ap_, bp, r2p = ab(LIBFABRIC, "perseus", 16, 4, A100)
    assert r2v > 0.99 and r2p > 0.99
    assert ap_ < 0.35 * av          # alpha cut >= 65% (paper: 90%)
    ai_v, bi_v, _ = ab(IBRC, "coupled", 4, 8, H100)
    ai_p, bi_p, _ = ab(IBRC, "perseus", 4, 8, H100)
    assert bi_p < 0.7 * bi_v        # beta cut >= 30% (paper: up to 60%)
