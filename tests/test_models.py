"""Model zoo: per-arch smoke (reduced config, one step, no NaNs) +
decode/forward consistency (the cache logic must reproduce the full
forward distribution token-by-token)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.configs.base import reduce_for_smoke
from repro.configs.registry import ALL_ARCHS, ASSIGNED, get_config
from repro.models import transformer as T
from repro.models.registry import build_model

KEY = jax.random.PRNGKey(0)
B, TLEN = 2, 32


def _batch(cfg, key=KEY, t=TLEN):
    ks = jax.random.split(key, 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, t), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (B, t), 0, cfg.vocab),
    }
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(ks[2], (B, t, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(
            ks[3], (B, cfg.n_image_tokens, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", sorted(ALL_ARCHS))
def test_smoke_forward_one_step(arch):
    """Assignment requirement: reduced same-family config, one forward /
    train step on CPU, output shapes + no NaNs."""
    cfg = reduce_for_smoke(get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch} loss not finite"
    gnorm = sum(float(jnp.sum(g ** 2)) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch} bad grads"


@pytest.mark.parametrize("arch", sorted(ALL_ARCHS))
def test_smoke_decode_shapes(arch):
    cfg = reduce_for_smoke(get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    caches = model.init_caches(B, 64)
    memory = None
    if cfg.family == "audio":
        batch = _batch(cfg)
        memory = T.encode(params, cfg, batch["frames"].astype(cfg.jdtype))
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, caches2 = model.decode_step(params, tok, caches, jnp.int32(0),
                                        memory=memory)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch} decode NaN"
    # cache structure preserved
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize("arch", [
    "tinyllama-1.1b",        # pure global attention
    "gemma3-27b",            # local:global pattern + remainder layers
    "recurrentgemma-2b",     # RG-LRU + local attention
    "mamba2-780m",           # SSD state caches
    "dbrx-132b",             # MoE ffn
    "whisper-tiny",          # enc-dec with cross-attention
])
def test_decode_matches_forward(arch):
    """Prefill caches + one decode step must reproduce the full forward's
    next-token logits — validates every cache/ring-buffer/state path."""
    cfg = reduce_for_smoke(get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    t = 24
    batch = _batch(cfg, t=t)
    memory = None
    if cfg.family == "audio":
        memory = T.encode(params, cfg, batch["frames"].astype(cfg.jdtype))

    # full forward logits at every position
    from repro.models import layers as L
    x = L.embed(params["embed"], batch["tokens"], cfg.jdtype)
    full_logits = T.forward(params, cfg, x, memory=memory)

    # prefill on the first t-1 tokens, then decode token t-1
    pre = {k: (v[:, : t - 1] if k in ("tokens", "labels") else v)
           for k, v in batch.items()}
    logits_p, caches, mem2 = model.prefill(params, pre, max_len=48)
    # decode caches sized to the same max_len as prefill produced
    last_tok = batch["tokens"][:, t - 1: t]
    dec_logits, _ = model.decode_step(
        params, last_tok, caches, jnp.int32(t - 1), memory=memory,
    )
    ref = np.asarray(full_logits[:, t - 1], np.float32)
    got = np.asarray(dec_logits, np.float32)
    # compare top-1 agreement and numeric closeness
    assert_allclose(got, ref, rtol=2e-3, atol=2e-3)


def test_periodic_pattern_layer_count():
    cfg = get_config("gemma3-27b")
    n_per, n_rem = cfg.n_periods()
    assert n_per * len(cfg.pattern) + n_rem == cfg.n_layers
    assert n_rem == 2  # 62 = 10*6 + 2


def test_param_counts_plausible():
    """Config-level parameter accounting lands near the public sizes."""
    expect = {
        "tinyllama-1.1b": (0.9e9, 1.4e9),
        "granite-8b": (7e9, 9.5e9),
        "internlm2-20b": (17e9, 23e9),
        "gemma3-27b": (23e9, 32e9),
        "dbrx-132b": (115e9, 150e9),
        "kimi-k2-1t-a32b": (0.85e12, 1.2e12),
        "mamba2-780m": (0.6e9, 1.0e9),
        "recurrentgemma-2b": (2.2e9, 3.6e9),
        "llava-next-34b": (30e9, 40e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B not in [{lo/1e9}, {hi/1e9}]"
    # MoE active params far below total
    kimi = get_config("kimi-k2-1t-a32b")
    assert kimi.active_param_count() < 0.08 * kimi.param_count()


def test_long_context_skips():
    from repro.configs.registry import cell_supported
    from repro.configs.base import LM_SHAPES
    long = LM_SHAPES["long_500k"]
    runs = {a: cell_supported(get_config(a), long)[0] for a in ASSIGNED}
    assert runs["mamba2-780m"] and runs["recurrentgemma-2b"] \
        and runs["gemma3-27b"]
    for a in ("dbrx-132b", "kimi-k2-1t-a32b", "granite-8b",
              "internlm2-20b", "tinyllama-1.1b", "whisper-tiny",
              "llava-next-34b"):
        assert not runs[a], f"{a} should skip long_500k"
