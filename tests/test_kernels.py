"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.kernels import ops, ref

RNG = np.random.RandomState(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# expert_gemm
# --------------------------------------------------------------------------


@pytest.mark.parametrize("E,T,H,F", [
    (1, 16, 8, 8),
    (3, 64, 32, 48),
    (4, 100, 24, 56),       # non-multiple-of-block sizes
    (2, 128, 128, 128),     # MXU-aligned
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_expert_ffn_sweep(E, T, H, F, dtype):
    x = jnp.asarray(RNG.randn(E, T, H), dtype) * 0.3
    w1 = jnp.asarray(RNG.randn(E, H, F), dtype) * 0.2
    w3 = jnp.asarray(RNG.randn(E, H, F), dtype) * 0.2
    w2 = jnp.asarray(RNG.randn(E, F, H), dtype) * 0.2
    got = ops.expert_ffn(x, w1, w3, w2, block_t=32, block_f=16)
    exp = ref.expert_ffn_ref(x, w1, w3, w2)
    assert_allclose(np.asarray(got, np.float32), np.asarray(exp, np.float32),
                    **_tol(dtype))


@pytest.mark.parametrize("activation", ["silu", "gelu"])
def test_expert_ffn_activations(activation):
    E, T, H, F = 2, 32, 16, 24
    x = jnp.asarray(RNG.randn(E, T, H), jnp.float32) * 0.3
    w1 = jnp.asarray(RNG.randn(E, H, F), jnp.float32) * 0.2
    w3 = jnp.asarray(RNG.randn(E, H, F), jnp.float32) * 0.2
    w2 = jnp.asarray(RNG.randn(E, F, H), jnp.float32) * 0.2
    got = ops.expert_ffn(x, w1, w3, w2, activation=activation, block_t=16,
                         block_f=8)
    exp = ref.expert_ffn_ref(x, w1, w3, w2, activation=activation)
    assert_allclose(np.asarray(got), np.asarray(exp), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("e_local,C,H,F", [
    (1, 8, 16, 8),
    (3, 8, 24, 40),          # non-power-of-two shapes
])
def test_fused_megakernel_single_rank_matches_ffn_ref(e_local, C, H, F):
    """Kernel-level oracle for the fused megakernel on a 1-rank mesh: the
    dispatch/combine DMAs degenerate to local copies and the output must be
    exactly the per-expert gated MLP of the input tiles."""
    import functools
    from jax.sharding import Mesh, PartitionSpec as P

    from repro import compat
    from repro.kernels.fused_megakernel import fused_moe_dispatch

    x = jnp.asarray(RNG.randn(1, e_local, C, H), jnp.float32) * 0.3
    w1 = jnp.asarray(RNG.randn(e_local, H, F), jnp.float32) * 0.2
    w3 = jnp.asarray(RNG.randn(e_local, H, F), jnp.float32) * 0.2
    w2 = jnp.asarray(RNG.randn(e_local, F, H), jnp.float32) * 0.2
    mesh = Mesh(np.array(jax.devices()[:1]), ("model",))
    f = compat.shard_map(
        functools.partial(fused_moe_dispatch, axis_name="model"),
        mesh=mesh, in_specs=(P("model"), P(), P(), P()),
        out_specs=P("model"),
    )
    got = jax.jit(f)(x, w1, w3, w2)[0]          # (e, C, H)
    exp = ref.expert_ffn_ref(x[0], w1, w3, w2)
    assert_allclose(np.asarray(got), np.asarray(exp), rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# flash_attention
# --------------------------------------------------------------------------


@pytest.mark.parametrize("B,Hq,Hkv,T,D", [
    (1, 1, 1, 32, 8),
    (2, 4, 2, 64, 16),       # GQA 2:1
    (1, 8, 1, 128, 32),      # MQA
    (2, 6, 3, 96, 16),       # non-power-of-two heads
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, Hq, Hkv, T, D, causal, dtype):
    q = jnp.asarray(RNG.randn(B, Hq, T, D), dtype) * 0.5
    k = jnp.asarray(RNG.randn(B, Hkv, T, D), dtype) * 0.5
    v = jnp.asarray(RNG.randn(B, Hkv, T, D), dtype) * 0.5
    got = ops.flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    exp = ref.attention_ref(q, k, v, causal=causal)
    assert_allclose(np.asarray(got, np.float32), np.asarray(exp, np.float32),
                    **_tol(dtype))


def test_flash_attention_block_shape_independence():
    B, Hq, Hkv, T, D = 1, 2, 1, 128, 16
    q = jnp.asarray(RNG.randn(B, Hq, T, D), jnp.float32) * 0.5
    k = jnp.asarray(RNG.randn(B, Hkv, T, D), jnp.float32) * 0.5
    v = jnp.asarray(RNG.randn(B, Hkv, T, D), jnp.float32) * 0.5
    outs = [
        np.asarray(ops.flash_attention(q, k, v, block_q=bq, block_k=bk))
        for bq, bk in [(16, 16), (32, 64), (128, 128), (64, 16)]
    ]
    for o in outs[1:]:
        assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# ssd_scan
# --------------------------------------------------------------------------


@pytest.mark.parametrize("B,L,H,Dh,N,chunk", [
    (1, 16, 1, 4, 2, 8),
    (2, 64, 3, 8, 4, 16),
    (1, 128, 2, 16, 8, 32),
    (2, 96, 4, 8, 4, 32),    # L not a multiple of 2*chunk
])
def test_ssd_scan_sweep(B, L, H, Dh, N, chunk):
    x = jnp.asarray(RNG.randn(B, L, H, Dh), jnp.float32) * 0.5
    dt = jnp.asarray(np.abs(RNG.randn(B, L, H)) * 0.1 + 0.01, jnp.float32)
    a = jnp.asarray(-np.abs(RNG.randn(H)) - 0.1, jnp.float32)
    bm = jnp.asarray(RNG.randn(B, L, H, N), jnp.float32) * 0.3
    cm = jnp.asarray(RNG.randn(B, L, H, N), jnp.float32) * 0.3
    got = ops.ssd_scan(x, dt, a, bm, cm, chunk=chunk)
    exp = ref.ssd_scan_ref(x, dt, a, bm, cm)
    assert_allclose(np.asarray(got), np.asarray(exp), rtol=3e-4, atol=3e-4)


def test_ssd_scan_strong_decay_stable():
    """Strong decay regime must not produce inf/nan (masked-exp bug guard)."""
    B, L, H, Dh, N = 1, 64, 2, 8, 4
    x = jnp.asarray(RNG.randn(B, L, H, Dh), jnp.float32)
    dt = jnp.full((B, L, H), 2.0, jnp.float32)      # large steps
    a = jnp.asarray([-8.0, -16.0], jnp.float32)     # strong decay
    bm = jnp.asarray(RNG.randn(B, L, H, N), jnp.float32)
    cm = jnp.asarray(RNG.randn(B, L, H, N), jnp.float32)
    got = ops.ssd_scan(x, dt, a, bm, cm, chunk=16)
    assert bool(jnp.all(jnp.isfinite(got)))
    exp = ref.ssd_scan_ref(x, dt, a, bm, cm)
    assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# models' jnp SSD path == kernel == naive recurrence
# --------------------------------------------------------------------------


def test_models_ssd_chunked_matches_kernel():
    from repro.configs.base import ArchConfig, LayerSpec
    from repro.models import layers as L

    cfg = ArchConfig(
        name="t", family="ssm", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=0, vocab=64, ssm_state=8, ssm_head_dim=16,
        pattern=(LayerSpec(mixer="ssd", ffn="none"),), dtype="float32",
    )
    key = jax.random.PRNGKey(0)
    p = L.init_ssd(key, cfg)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32)) * 0.3
    full = L.ssd_fwd(p, cfg, u, chunk=16)
    full2 = L.ssd_fwd(p, cfg, u, chunk=64)
    assert_allclose(np.asarray(full), np.asarray(full2), rtol=2e-4, atol=2e-4)
