"""Sharding rules: every derived spec must divide its array exactly
(explicit input shardings reject padding), for every arch on both meshes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LM_SHAPES
from repro.configs.registry import ASSIGNED, get_config
from repro.models import transformer as T
from repro.optim.adamw import init_opt
from repro.parallel import sharding as shd


MESHES = {
    "single": ({"data": 16, "model": 16}, ("data",)),
    "multi": ({"pod": 2, "data": 16, "model": 16}, ("pod", "data")),
}


def _axes(mesh_kind):
    sizes, data_axes = MESHES[mesh_kind]
    dsz = 1
    for a in data_axes:
        dsz *= sizes[a]
    return shd.MeshAxes(data=data_axes, data_size=dsz,
                        model_size=sizes["model"]), sizes


def _check_divisible(specs, tree, sizes, what):
    flat_s = jax.tree.leaves(
        specs, is_leaf=lambda x: hasattr(x, "index_sharding") or
        x.__class__.__name__ == "PartitionSpec")
    flat_t = jax.tree.leaves(tree)
    assert len(flat_s) == len(flat_t)
    for spec, leaf in zip(flat_s, flat_t):
        for dim, part in zip(leaf.shape, tuple(spec)):
            if part is None:
                continue
            parts = part if isinstance(part, tuple) else (part,)
            size = 1
            for p in parts:
                size *= sizes[p]
            assert dim % size == 0, (
                f"{what}: dim {dim} not divisible by {part}={size} "
                f"(leaf shape {leaf.shape}, spec {spec})"
            )


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
@pytest.mark.parametrize("mesh_kind", ["single", "multi"])
def test_param_specs_divide(arch, mesh_kind):
    cfg = get_config(arch)
    axes, sizes = _axes(mesh_kind)
    params = jax.eval_shape(
        lambda k: T.init(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    specs = shd.param_specs(params, cfg, axes, fsdp=cfg.is_moe)
    _check_divisible(specs, params, sizes, f"{arch} params")
    opt = jax.eval_shape(init_opt, params)
    specs_mu = shd.param_specs(opt.mu, cfg, axes, fsdp=cfg.is_moe)
    _check_divisible(specs_mu, opt.mu, sizes, f"{arch} opt.mu")


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
@pytest.mark.parametrize("shape_name", list(LM_SHAPES))
@pytest.mark.parametrize("mesh_kind", ["single", "multi"])
def test_cache_and_batch_specs_divide(arch, shape_name, mesh_kind):
    from repro.configs.registry import cell_supported
    from repro.data.synthetic import make_batch_struct

    cfg = get_config(arch)
    shape = LM_SHAPES[shape_name]
    if not cell_supported(cfg, shape)[0]:
        pytest.skip("cell skipped by assignment")
    axes, sizes = _axes(mesh_kind)
    batch = make_batch_struct(cfg, shape)
    bspecs = shd.batch_specs(cfg, shape, axes)
    _check_divisible(
        {k: bspecs[k] for k in batch}, batch, sizes,
        f"{arch}/{shape_name} batch",
    )
    if shape.kind == "decode":
        caches = jax.eval_shape(
            lambda: T.init_caches(cfg, shape.global_batch, shape.seq_len,
                                  cfg.jdtype)
        )
        cspecs = shd.cache_specs(cfg, shape, caches, axes)
        _check_divisible(cspecs, caches, sizes, f"{arch}/{shape_name} cache")


def test_moe_experts_divide_model_axis():
    """EP requires exact divisibility (shard_map): every MoE arch must
    place an integer number of experts per model rank."""
    for arch in ("dbrx-132b", "kimi-k2-1t-a32b"):
        cfg = get_config(arch)
        assert cfg.n_experts % 16 == 0


def test_embedding_fallback_rules():
    """Indivisible vocabs fall back to hidden-dim sharding (never padded)."""
    for arch, div in (("mamba2-780m", False), ("whisper-tiny", False),
                      ("tinyllama-1.1b", True)):
        cfg = get_config(arch)
        assert (cfg.vocab % 16 == 0) == div
