"""Protocol-layer invariants: schedules preserve put-with-signal ordering."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.signaling import (
    Op, OpKind, ScheduleKind, Transfer, build_schedule, fence_count,
    group_by_destination, moe_dispatch_transfers, optimal_group_size,
)


def _mk_transfers(n, n_dest=4, nbytes=1024):
    return [
        Transfer(tag=i, dest_pe=i % n_dest, nbytes=nbytes,
                 dest_node=1 + (i % n_dest) // 2)
        for i in range(n)
    ]


def _ordering_ok(ops):
    """Every SIGNAL for tag t must be preceded by (a) its PUT, and (b) a
    FENCE (or carry the NIC flag) issued after that PUT — the
    put-with-signal guarantee the proxy/NIC must enforce."""
    put_pos = {}
    fence_after = []
    for i, op in enumerate(ops):
        if op.kind is OpKind.PUT:
            put_pos[op.tag] = i
        elif op.kind is OpKind.FENCE:
            fence_after.append(i)
        elif op.kind in (OpKind.SIGNAL, OpKind.SIGNAL_FENCED):
            if op.tag not in put_pos:
                return False
            if op.kind is OpKind.SIGNAL_FENCED:
                continue  # NIC flag orders within the QP (peer-pinned)
            # plain signal: needs a proxy fence between the PUT and itself,
            # or an earlier flagged signal on the same destination.
            p = put_pos[op.tag]
            covered = any(p < f < i for f in fence_after) or any(
                o.kind is OpKind.SIGNAL_FENCED and o.dest_pe == op.dest_pe
                and p < j < i
                for j, o in enumerate(ops[:i])
            )
            if not covered:
                return False
    return True


@pytest.mark.parametrize("kind", list(ScheduleKind))
@pytest.mark.parametrize("n", [1, 3, 16, 96])
def test_schedules_preserve_ordering(kind, n):
    transfers = _mk_transfers(n)
    sched = build_schedule(transfers, kind)
    if kind is ScheduleKind.PUT_ONLY:
        assert sched.n_fences == 0
        return
    assert _ordering_ok(sched.ops), f"{kind} violates put-before-signal"


@pytest.mark.parametrize("kind,expected", [
    (ScheduleKind.COUPLED, 96),
    (ScheduleKind.NIC_ORDERED, 96),
    (ScheduleKind.DECOUPLED, 12),   # per-PE default: 12 remote PEs
    (ScheduleKind.PERSEUS, 12),
])
def test_fence_counts_running_example(kind, expected):
    """The paper's running example: Qwen3-30B, 4 nodes x 4 GPUs, 128
    experts -> 96 remote transfers to 12 remote PEs; Perseus cuts fences
    8x (96 -> 12)."""
    transfers = moe_dispatch_transfers(
        my_pe=0, n_pe=16, pe_per_node=4, n_experts=128,
        bytes_per_expert=16384,
    )
    assert len(transfers) == 96
    assert len({t.dest_pe for t in transfers}) == 12
    sched = build_schedule(transfers, kind)
    assert sched.n_fences == expected


def test_every_transfer_signaled_once():
    transfers = _mk_transfers(37, n_dest=5)
    for kind in (ScheduleKind.COUPLED, ScheduleKind.DECOUPLED,
                 ScheduleKind.NIC_ORDERED, ScheduleKind.PERSEUS):
        sched = build_schedule(transfers, kind)
        sig_tags = sorted(
            o.tag for o in sched.ops
            if o.kind in (OpKind.SIGNAL, OpKind.SIGNAL_FENCED)
        )
        assert sig_tags == sorted(t.tag for t in transfers)
        put_tags = sorted(o.tag for o in sched.ops if o.kind is OpKind.PUT)
        assert put_tags == sorted(t.tag for t in transfers)


@settings(max_examples=200, deadline=None)
@given(
    n=st.integers(1, 200),
    n_dest=st.integers(1, 31),
    group_size=st.one_of(st.none(), st.integers(1, 64)),
    kind=st.sampled_from([ScheduleKind.DECOUPLED, ScheduleKind.PERSEUS,
                          ScheduleKind.COUPLED, ScheduleKind.NIC_ORDERED]),
)
def test_schedule_properties(n, n_dest, group_size, kind):
    """Property: any schedule preserves ordering, signals each transfer
    exactly once, and matches the closed-form fence count."""
    transfers = _mk_transfers(n, n_dest=n_dest)
    sched = build_schedule(transfers, kind, group_size=group_size)
    assert _ordering_ok(sched.ops)
    sig_tags = sorted(
        o.tag for o in sched.ops
        if o.kind in (OpKind.SIGNAL, OpKind.SIGNAL_FENCED)
    )
    assert sig_tags == list(range(n))
    n_dest_actual = len({t.dest_pe for t in transfers})
    expected = fence_count(n, kind, group_size, n_dest_actual)
    if kind is ScheduleKind.PERSEUS and group_size is not None:
        # closed form is a lower bound when tuned groups span destinations
        assert expected <= sched.n_fences <= n
    else:
        assert sched.n_fences == expected


@settings(max_examples=100, deadline=None)
@given(n=st.integers(1, 300), gs=st.integers(1, 100))
def test_grouping_partition(n, gs):
    """Groups partition the transfer set: disjoint cover, order-stable."""
    transfers = _mk_transfers(n, n_dest=7)
    groups = group_by_destination(transfers, gs)
    flat = [t.tag for g in groups for t in g]
    assert sorted(flat) == list(range(n))
    assert all(len(g) <= gs for g in groups)
    # per-PE grouping: each group single-destination
    for g in group_by_destination(transfers, None):
        assert len({t.dest_pe for t in g}) == 1


@pytest.mark.parametrize("kind", list(ScheduleKind))
@pytest.mark.parametrize("group_size", [None, 1, 2, 3, 5, 8, 64])
@pytest.mark.parametrize("n,n_dest", [(1, 1), (7, 3), (24, 6), (96, 12)])
def test_fence_count_closed_form(kind, group_size, n, n_dest):
    """``fence_count`` closed form vs ``Schedule.n_fences`` over every
    ScheduleKind x group size, including PERSEUS groups spanning multiple
    destinations (transfers are dealt round-robin over destinations, so any
    tuned group_size > 1 with n_dest > 1 produces multi-destination groups,
    where the docstring admits the closed form is only a lower bound)."""
    transfers = _mk_transfers(n, n_dest=n_dest)
    sched = build_schedule(transfers, kind, group_size=group_size)
    n_dest_actual = len({t.dest_pe for t in transfers})
    expected = fence_count(n, kind, group_size, n_dest_actual)
    if kind is ScheduleKind.PERSEUS and group_size is not None:
        # Exact count: one flagged signal per distinct destination per group.
        exact = sum(
            len({t.dest_pe for t in g})
            for g in group_by_destination(transfers, group_size)
        )
        assert sched.n_fences == exact
        assert expected <= sched.n_fences <= n      # documented lower bound
    else:
        assert sched.n_fences == expected


def test_optimal_group_size_bounds():
    for n in (1, 12, 96, 112):
        g = optimal_group_size(n, drain_base_us=60.0, per_put_wait_us=1.0)
        assert 1 <= g <= n
