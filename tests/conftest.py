"""Test-suite bootstrap: fall back to the bundled hypothesis shim.

``hypothesis`` is an optional dependency of this suite; several modules use
it for property tests.  When it's missing the tier-1 run must still collect
and execute (the shim turns property tests into bounded seeded sweeps).
"""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

try:  # pragma: no cover - depends on the environment
    import hypothesis  # noqa: F401
except ImportError:
    from _hypothesis_shim import install

    install()
