"""Elastic scaling: checkpoint -> restore on a different mesh shape.

The scale-change runs in a subprocess (fake devices must be configured
before jax initializes): train state saved under a 4-device (2x2) mesh is
restored under an 8-device (4x2) mesh and training resumes bitwise on the
restored parameters.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.elastic import plan_rescale

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_plan_rescale_math():
    p = plan_rescale(16, 8)
    assert p.grad_accum_multiplier == 2 and p.keeps_global_batch
    p = plan_rescale(8, 16)
    assert p.grad_accum_multiplier == 1 and p.keeps_global_batch
    with pytest.raises(ValueError):
        plan_rescale(8, 0)


@pytest.mark.slow
def test_restore_across_mesh_shapes(tmp_path):
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, {SRC!r})
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.checkpoint.manager import CheckpointManager
        from repro.runtime.elastic import rescale_state

        devs = np.array(jax.devices())
        mesh_a = Mesh(devs[:4].reshape(2, 2), ("data", "model"))
        mesh_b = Mesh(devs.reshape(4, 2), ("data", "model"))

        tree = {{"w": jnp.arange(64.0).reshape(8, 8),
                 "b": jnp.arange(8.0)}}
        specs = {{"w": P("data", "model"), "b": P()}}

        # "train" on mesh A: place sharded, bump, save
        sh_a = jax.tree.map(lambda s: NamedSharding(mesh_a, s), specs,
                            is_leaf=lambda x: isinstance(x, P))
        placed = jax.tree.map(jax.device_put, tree, sh_a)
        placed = jax.tree.map(lambda x: x + 1.0, placed)
        mgr = CheckpointManager({str(tmp_path)!r}, async_save=False)
        mgr.save(7, placed, metadata={{"dp": 2}})

        # resume on mesh B (scale-up 2 -> 4 data-parallel)
        restored, meta = rescale_state(mgr, tree, mesh_b, specs)
        got = np.asarray(restored["w"])
        want = np.arange(64.0).reshape(8, 8) + 1.0
        assert np.array_equal(got, want), got
        shard_shape = restored["w"].sharding.shard_shape((8, 8))
        assert shard_shape == (2, 4), shard_shape   # 4-way data, 2-way model
        print("ELASTIC_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600)
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr[-2000:]
