"""End-to-end training driver: a ~100M-param MoE transformer for a few
hundred steps with checkpoint/restart mid-run.

This exercises the full stack the paper's workload depends on: synthetic
data pipeline -> PeriodicDecoder with MoE FFN (capacity dispatch, the same
routing the Perseus megakernel serves) -> AdamW -> fault-tolerant trainer
with an *injected crash* at step 60, recovered from the last checkpoint.

Run:  PYTHONPATH=src python examples/train_moe_e2e.py  (~10-20 min on CPU)
Quick: PYTHONPATH=src python examples/train_moe_e2e.py --steps 40 --dim 64
"""

import argparse
import dataclasses

import jax

from repro.configs.base import ArchConfig, LayerSpec, LM_SHAPES
from repro.data.synthetic import SyntheticDataset
from repro.models.registry import build_model
from repro.optim.adamw import OptConfig
from repro.runtime.train_loop import TrainConfig, Trainer, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--dim", type=int, default=256)
ap.add_argument("--layers", type=int, default=6)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--ckpt-dir", default="/tmp/repro_moe_e2e")
args = ap.parse_args()

# ~100M params at the default settings (vocab 8192, d=256, 6 MoE layers
# of 16 experts): same family as qwen3-30b-a3b, shrunk to CPU scale.
cfg = ArchConfig(
    name="moe-100m", family="moe",
    n_layers=args.layers, d_model=args.dim, n_heads=8, n_kv_heads=4,
    d_ff=args.dim * 4, d_ff_expert=args.dim * 2, vocab=8192,
    n_experts=16, top_k=2,
    pattern=(LayerSpec(mixer="attn", ffn="moe"),),
    dtype="float32",
)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
n = sum(x.size for x in jax.tree.leaves(params))
print(f"model: {n/1e6:.1f}M params ({cfg.n_experts} experts top-{cfg.top_k})")

ds = SyntheticDataset(cfg, LM_SHAPES["train_4k"], seed=0,
                      batch_override=args.batch, seq_override=args.seq)
step = make_train_step(
    model.loss,
    OptConfig(lr=3e-4, warmup_steps=10, total_steps=args.steps),
)

crash = {"armed": True}


def fault_hook(i):
    if crash["armed"] and i == min(60, args.steps - 10):
        crash["armed"] = False
        raise RuntimeError("injected node failure")


trainer = Trainer(
    step, ds, params,
    TrainConfig(steps=args.steps, ckpt_every=20, ckpt_dir=args.ckpt_dir,
                log_every=20),
    fault_hook=fault_hook,
)
history = trainer.run()
first = sum(h["loss"] for h in history[:5]) / 5
last = sum(h["loss"] for h in history[-5:]) / 5
print(f"\nloss {first:.3f} -> {last:.3f} | restarts={trainer.restarts} "
      f"| steps replayed after crash: yes" if trainer.restarts else "")
assert last < first, "training failed to reduce loss"
print("OK: trained through an injected failure with checkpoint recovery")
