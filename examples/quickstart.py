"""Quickstart: the paper's mechanism in five minutes.

1. Builds the Qwen3-30B dispatch schedule (vanilla vs Perseus) and shows
   the fence-count collapse (96 -> 12 in the running example).
2. Runs both through the calibrated proxy/NIC simulator to reproduce the
   signaling-efficiency cliff and its recovery (Fig. 5a / Fig. 14).
3. Runs the actual JAX MoE block with the dense oracle vs the gathered
   backend to show numerical equivalence of the dispatch machinery.
4. Runs the fused megakernel backend (dispatch + expert FFN + combine in
   one Pallas kernel, interpret mode) against the same oracle, and shows
   the modeled staged-vs-fused overlap win at a decode-size batch.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.moe import MoEConfig, init_moe, moe_apply
from repro.core.signaling import build_schedule, moe_dispatch_transfers
from repro.core.transport_sim import (
    LIBFABRIC, QWEN3_30B, signaling_efficiency, simulate_moe_layer,
    simulate_proxy,
)

# -- 1. schedules ----------------------------------------------------------
transfers = moe_dispatch_transfers(
    my_pe=0, n_pe=16, pe_per_node=4, n_experts=128,
    bytes_per_expert=64 * 2048 * 2,   # EC=64 tokens of H=2048 bf16
)
print(f"dispatch: {len(transfers)} remote expert tiles -> "
      f"{len({t.dest_pe for t in transfers})} remote PEs")
for kind in ("coupled", "decoupled", "nic_ordered", "perseus"):
    s = build_schedule(transfers, kind)
    print(f"  {kind:12s} fences={s.n_fences:3d} proxy_fences={s.n_proxy_fences}")

# -- 2. simulator ----------------------------------------------------------
print("\nsignaling efficiency (96 x 4KB transfers, Fig. 5a/14):")
for nodes in (2, 4, 8):
    ev = signaling_efficiency(n_transfers=96, nbytes=4096, n_nodes=nodes,
                              params=LIBFABRIC, kind="coupled")
    ep = signaling_efficiency(n_transfers=96, nbytes=4096, n_nodes=nodes,
                              params=LIBFABRIC, kind="perseus")
    print(f"  {nodes} nodes: vanilla {ev*100:5.1f}%  perseus {ep*100:5.1f}%")

r = simulate_proxy(build_schedule(transfers, "coupled"), LIBFABRIC, n_nodes=4)
print(f"\nvanilla dispatch (4 nodes): total {r.total_time/1e3:.2f} ms, "
      f"proxy stalled {r.proxy_stall/1e3:.2f} ms "
      f"({100*r.proxy_stall/r.total_time:.0f}%)")
r = simulate_proxy(build_schedule(transfers, "perseus"), LIBFABRIC, n_nodes=4)
print(f"perseus dispatch (4 nodes): total {r.total_time/1e3:.2f} ms, "
      f"proxy stalled {r.proxy_stall/1e3:.2f} ms")

# -- 3. the real MoE block --------------------------------------------------
cfg = MoEConfig(d_model=64, d_ff=128, n_experts=8, top_k=2,
                dtype=jnp.float32, capacity_factor=4.0)
params = init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (128, 64))
dense = moe_apply(params, cfg, x, backend="dense")
gathered = moe_apply(params, cfg, x, backend="gathered")
err = float(jnp.abs(dense - gathered).max())
print(f"\nMoE backends: |dense - gathered|_max = {err:.2e}")
print("(EP collective / Pallas megakernel backends validated in "
      "tests/test_moe.py under a multi-device mesh)")

# -- 4. the fused megakernel -------------------------------------------------
# Dispatch DMAs + per-tile expert gated-MLP + combine DMAs in ONE Pallas
# kernel (interpret mode on CPU; Mosaic on TPU).  On this 1-device mesh the
# remote copies degenerate to local DMAs, but it is the same kernel code
# path the multi-rank tests sweep.
mesh = Mesh(np.array(jax.devices()[:1]), ("model",))
cfg_f = MoEConfig(d_model=64, d_ff=128, n_experts=8, top_k=2,
                  dtype=jnp.float32, capacity_factor=4.0,
                  token_axes=("model",))
fused = jax.jit(
    lambda p, x: moe_apply(p, cfg_f, x, backend="fused", mesh=mesh)
)(params, x)
err = float(jnp.abs(dense - fused).max())
print(f"fused megakernel backend: |dense - fused|_max = {err:.2e}")

# Modeled A/B: the staged path waits on ALL recv signals before the first
# GEMM; the fused kernel starts each tile on its own signal.
for tag, s in (("decode S=16", 16), ("prefill S=1K", 1024)):
    stg = simulate_moe_layer(QWEN3_30B, tokens_per_pe=s, n_nodes=4,
                             pe_per_node=4, transport=LIBFABRIC,
                             schedule="perseus", fused=False)
    fus = simulate_moe_layer(QWEN3_30B, tokens_per_pe=s, n_nodes=4,
                             pe_per_node=4, transport=LIBFABRIC,
                             schedule="perseus", fused=True)
    last_sig = max(fus.dispatch.signal_visible.values())
    print(f"staged vs fused ({tag}): {stg.latency_us:.0f} -> "
          f"{fus.latency_us:.0f} us ({stg.latency_us/fus.latency_us:.2f}x), "
          f"util {stg.utilization:.2f} -> {fus.utilization:.2f}; first "
          f"compute @{fus.first_compute_us:.1f} us vs last signal "
          f"@{last_sig:.1f} us")
