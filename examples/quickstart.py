"""Quickstart: the paper's mechanism in five minutes.

1. Builds the Qwen3-30B dispatch schedule (vanilla vs Perseus) and shows
   the fence-count collapse (96 -> 12 in the running example).
2. Runs both through the calibrated proxy/NIC simulator to reproduce the
   signaling-efficiency cliff and its recovery (Fig. 5a / Fig. 14).
3. Runs the actual JAX MoE block with the dense oracle vs the gathered
   backend to show numerical equivalence of the dispatch machinery.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.moe import MoEConfig, init_moe, moe_apply
from repro.core.signaling import build_schedule, moe_dispatch_transfers
from repro.core.transport_sim import LIBFABRIC, signaling_efficiency, simulate_proxy

# -- 1. schedules ----------------------------------------------------------
transfers = moe_dispatch_transfers(
    my_pe=0, n_pe=16, pe_per_node=4, n_experts=128,
    bytes_per_expert=64 * 2048 * 2,   # EC=64 tokens of H=2048 bf16
)
print(f"dispatch: {len(transfers)} remote expert tiles -> "
      f"{len({t.dest_pe for t in transfers})} remote PEs")
for kind in ("coupled", "decoupled", "nic_ordered", "perseus"):
    s = build_schedule(transfers, kind)
    print(f"  {kind:12s} fences={s.n_fences:3d} proxy_fences={s.n_proxy_fences}")

# -- 2. simulator ----------------------------------------------------------
print("\nsignaling efficiency (96 x 4KB transfers, Fig. 5a/14):")
for nodes in (2, 4, 8):
    ev = signaling_efficiency(n_transfers=96, nbytes=4096, n_nodes=nodes,
                              params=LIBFABRIC, kind="coupled")
    ep = signaling_efficiency(n_transfers=96, nbytes=4096, n_nodes=nodes,
                              params=LIBFABRIC, kind="perseus")
    print(f"  {nodes} nodes: vanilla {ev*100:5.1f}%  perseus {ep*100:5.1f}%")

r = simulate_proxy(build_schedule(transfers, "coupled"), LIBFABRIC, n_nodes=4)
print(f"\nvanilla dispatch (4 nodes): total {r.total_time/1e3:.2f} ms, "
      f"proxy stalled {r.proxy_stall/1e3:.2f} ms "
      f"({100*r.proxy_stall/r.total_time:.0f}%)")
r = simulate_proxy(build_schedule(transfers, "perseus"), LIBFABRIC, n_nodes=4)
print(f"perseus dispatch (4 nodes): total {r.total_time/1e3:.2f} ms, "
      f"proxy stalled {r.proxy_stall/1e3:.2f} ms")

# -- 3. the real MoE block --------------------------------------------------
cfg = MoEConfig(d_model=64, d_ff=128, n_experts=8, top_k=2,
                dtype=jnp.float32, capacity_factor=4.0)
params = init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (128, 64))
dense = moe_apply(params, cfg, x, backend="dense")
gathered = moe_apply(params, cfg, x, backend="gathered")
err = float(jnp.abs(dense - gathered).max())
print(f"\nMoE backends: |dense - gathered|_max = {err:.2e}")
print("(EP collective / Pallas megakernel backends validated in "
      "tests/test_moe.py under a multi-device mesh)")
