"""Perseus ablation walkthrough (paper §6.3 + beyond-paper extensions).

Sweeps the two mechanisms independently and combined across node counts,
then demonstrates the three beyond-paper optimizations from DESIGN.md §10:

  * analytic adaptive group sizing (vs the paper's fixed per-PE grouping),
  * coalesced per-destination signal words (1 signal op per group),
  * latency-aware group ordering (slowest destination first).

Run:  PYTHONPATH=src python examples/perseus_ablation.py
"""

from repro.core.signaling import (
    ScheduleKind, build_schedule, moe_dispatch_transfers, optimal_group_size,
)
from repro.core.transport_sim import (
    LIBFABRIC, QWEN3_30B, simulate_forward, simulate_moe_layer,
    simulate_proxy,
)


def fwd(sched, n, s=1024, **kw):
    return simulate_forward(
        QWEN3_30B, tokens_per_pe=s, n_nodes=n, pe_per_node=4,
        transport=LIBFABRIC, schedule=sched, **kw,
    )


print("ablation (Qwen3-30B, S=1K, speedup over vanilla):")
print(f"{'nodes':>6} {'decoupled':>10} {'nic_only':>10} {'perseus':>10}")
for n in (2, 4, 8, 16):
    v = fwd("coupled", n)
    print(f"{n:6d} {v/fwd('decoupled', n):10.2f} "
          f"{v/fwd('nic_ordered', n):10.2f} {v/fwd('perseus', n):10.2f}")
print("(paper Fig. 10: decoupled wins at 2 nodes, NIC-side wins at 8, "
      "combined 1.5-3.5x at 8 nodes)")

# ---- beyond-paper: adaptive group size -----------------------------------
print("\nbeyond-paper: adaptive group sizing (8 nodes, decoupled):")
transfers = moe_dispatch_transfers(
    my_pe=0, n_pe=32, pe_per_node=4, n_experts=128,
    bytes_per_expert=64 * 2048 * 2,
)
base = simulate_proxy(
    build_schedule(transfers, "decoupled"), LIBFABRIC, n_nodes=8
).total_time
g_star = optimal_group_size(len(transfers), drain_base_us=63.0,
                            per_put_wait_us=1.2)
adaptive = simulate_proxy(
    build_schedule(transfers, "decoupled", group_size=g_star),
    LIBFABRIC, n_nodes=8,
).total_time
print(f"  per-PE default: {base/1e3:.2f} ms | analytic g*={g_star}: "
      f"{adaptive/1e3:.2f} ms ({base/adaptive:.2f}x)")

# ---- beyond-paper: latency-aware ordering --------------------------------
slow_first = sorted(transfers, key=lambda t: -t.dest_node)
fast_first = sorted(transfers, key=lambda t: t.dest_node)
t_slow = simulate_proxy(build_schedule(slow_first, "perseus"),
                        LIBFABRIC, n_nodes=8).total_time
t_fast = simulate_proxy(build_schedule(fast_first, "perseus"),
                        LIBFABRIC, n_nodes=8).total_time
print(f"\nbeyond-paper: group ordering — slowest-dest-first "
      f"{t_slow/1e3:.3f} ms vs fastest-first {t_fast/1e3:.3f} ms "
      f"({t_fast/t_slow:.3f}x)")

# ---- beyond-paper: staged vs fused megakernel ----------------------------
# Even with the best signaling schedule, the *staged* kernel layout
# (dispatch -> barrier -> expert FFN -> barrier -> combine) leaves
# serialization on the table; fusing compute into the dispatch kernel
# (backend="fused") starts each tile's GEMMs on its own recv signal.
print("\nbeyond-paper: staged vs fused megakernel (perseus schedule):")
for s in (16, 256, 1024):
    stg = simulate_moe_layer(QWEN3_30B, tokens_per_pe=s, n_nodes=8,
                             pe_per_node=4, transport=LIBFABRIC,
                             schedule="perseus", fused=False)
    fus = simulate_moe_layer(QWEN3_30B, tokens_per_pe=s, n_nodes=8,
                             pe_per_node=4, transport=LIBFABRIC,
                             schedule="perseus", fused=True)
    print(f"  S={s:5d}: {stg.latency_us/1e3:6.2f} -> "
          f"{fus.latency_us/1e3:6.2f} ms "
          f"({stg.latency_us/fus.latency_us:.2f}x), util "
          f"{stg.utilization:.2f} -> {fus.utilization:.2f}")

# ---- beyond-paper: coalesced signal words --------------------------------
# One 8B signal per destination carrying a bitfield of expert flags:
# receiver decodes 8 experts per word; signal op count drops k_dest x.
coalesced = [t for i, t in enumerate(transfers) if i % 8 == 0]
sched_c = build_schedule(transfers, "put_only").ops + build_schedule(
    coalesced, "perseus").ops[len(coalesced):]
t_c = simulate_proxy(sched_c, LIBFABRIC, n_nodes=8).total_time
t_p = simulate_proxy(build_schedule(transfers, "perseus"),
                     LIBFABRIC, n_nodes=8).total_time
print(f"beyond-paper: coalesced signal words {t_c/1e3:.3f} ms vs per-expert "
      f"signals {t_p/1e3:.3f} ms ({t_p/t_c:.2f}x on the dispatch phase)")
