"""Serving example: continuous-batching decode on three model families.

Decode is the paper's overhead-dominated regime (small S): every generated
token costs one expert dispatch per MoE layer, which is exactly the
per-expert put-with-signal traffic Perseus unblocks.  Here we serve reduced
configs of a dense (tinyllama), an MoE (dbrx) and an SSM (mamba2) arch
through the same Server.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax

from repro.configs.base import reduce_for_smoke
from repro.configs.registry import get_config
from repro.models.registry import build_model
from repro.runtime.serve_loop import Request, ServeConfig, Server

for arch in ("tinyllama-1.1b", "dbrx-132b", "mamba2-780m"):
    cfg = reduce_for_smoke(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    srv = Server(model, params, ServeConfig(slots=3, max_len=96))
    for rid in range(5):
        srv.submit(Request(rid=rid, prompt=[(rid * 7 + j) % cfg.vocab
                                            for j in range(1, 5)],
                           max_new_tokens=6))
    t0 = time.perf_counter()
    done = srv.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    print(f"{arch:18s} ({cfg.family:6s}): {len(done)} reqs, {toks} tokens, "
          f"{toks/dt:6.1f} tok/s  sample={done[0].out}")
print("OK: continuous batching served dense, MoE and SSM families")
