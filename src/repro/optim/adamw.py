"""AdamW + schedules + gradient utilities (self-contained, no optax)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "OptConfig", "OptState", "init_opt", "apply_updates",
    "cosine_schedule", "clip_by_global_norm", "global_norm",
]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    mu: Any
    nu: Any
    step: jax.Array


def _q_zeros(p):
    """int8 moment + per-row f32 scale (block-quantized optimizer state —
    a §Perf memory-term lever; 4 bytes -> ~1.03 bytes per moment)."""
    rows = p.shape[0] if p.ndim >= 1 else 1
    return {
        "q": jnp.zeros(p.shape, jnp.int8),
        "s": jnp.zeros((rows,) if p.ndim >= 1 else (), jnp.float32),
    }


def _q_load(m):
    if isinstance(m, dict) and "q" in m:
        s = m["s"]
        if s.ndim >= 1 and m["q"].ndim >= 1:
            s = s.reshape((-1,) + (1,) * (m["q"].ndim - 1))
        return m["q"].astype(jnp.float32) * s
    return m


def _q_store(val, like):
    if isinstance(like, dict) and "q" in like:
        # Scale granularity follows the existing state: per-row when the
        # stored scale has a leading axis, scalar otherwise (blocked
        # updates slice the row axis away — see apply_updates).
        if like["s"].ndim >= 1 and val.ndim >= 1:
            axes = tuple(range(1, val.ndim))
            s = jnp.max(jnp.abs(val), axis=axes) / 127.0 + 1e-12
            q = jnp.clip(jnp.round(
                val / s.reshape((-1,) + (1,) * (val.ndim - 1))
            ), -127, 127).astype(jnp.int8)
        else:
            s = jnp.max(jnp.abs(val)) / 127.0 + 1e-12
            q = jnp.clip(jnp.round(val / s), -127, 127).astype(jnp.int8)
        return {"q": q, "s": s}
    return val


def init_opt(params, *, quantize: bool = False) -> OptState:
    if quantize:
        mu = jax.tree.map(_q_zeros, params)
        nu = jax.tree.map(_q_zeros, params)
        return OptState(mu=mu, nu=nu, step=jnp.zeros((), jnp.int32))
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return OptState(mu=zeros, nu=jax.tree.map(jnp.copy, zeros),
                    step=jnp.zeros((), jnp.int32))


def cosine_schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / max(1, cfg.total_steps - cfg.warmup_steps),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)
    ))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def apply_updates(
    params, grads, state: OptState, cfg: OptConfig
) -> tuple[Any, OptState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = cosine_schedule(cfg, state.step)
    b1, b2 = cfg.beta1, cfg.beta2

    def upd(p, g, mu_st, nu_st):
        g = g.astype(jnp.float32)
        mu = b1 * _q_load(mu_st) + (1 - b1) * g
        nu = b2 * _q_load(nu_st) + (1 - b2) * g * g
        mu_hat = mu / (1 - b1 ** step)
        nu_hat = nu / (1 - b2 ** step)
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (delta + wd * p.astype(jnp.float32))
        return newp.astype(p.dtype), _q_store(mu, mu_st), _q_store(nu, nu_st)

    # NOTE(§Perf, refuted): a lax.map-blocked update over big stacked
    # leaves was tried to bound the dequantized-moment transients; on this
    # backend's buffer accounting the loop's xs/ys double-buffering cost
    # *more* than it saved (temp 65.7 -> 78.2 GB on kimi train_4k), so the
    # straight per-leaf update stays.
    is_q = lambda x: isinstance(x, dict) and "q" in x
    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state.mu, is_leaf=is_q)
    flat_nu = jax.tree.leaves(state.nu, is_leaf=is_q)
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = tree.unflatten([o[0] for o in out])
    new_state = OptState(
        mu=tree.unflatten([o[1] for o in out]),
        nu=tree.unflatten([o[2] for o in out]),
        step=step,
    )
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
