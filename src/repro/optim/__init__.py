"""repro.optim subsystem."""
