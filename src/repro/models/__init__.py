"""repro.models subsystem."""
