"""PeriodicDecoder: one decoder implementation for the whole model zoo.

A model is a repeating *pattern* of layer slots (``ArchConfig.pattern``);
parameters for slot ``s`` are stacked over the pattern periods and the stack
is consumed by one ``jax.lax.scan`` — HLO size and lowering time scale with
the pattern period, not with depth (62-layer gemma3 lowers as 6 slots x 10
periods + 2 remainder layers).

Entry points (all pure):

  ``init(key, cfg)``                                     -> params
  ``forward(params, cfg, batch, ...)``                   -> logits (+caches)
  ``init_caches(cfg, batch, max_len, dtype)``            -> decode caches
  ``decode_step(params, cfg, tokens_t, caches, pos, ...)``-> (logits, caches)

MoE FFN slots route through ``repro.core.moe`` — backend ``gathered`` on a
single device; under a mesh ``collective`` (shard_map all_to_all over the EP
axis), ``megakernel`` (staged Pallas remote-DMA dispatch) or ``fused``
(dispatch + expert FFN + combine in one Pallas kernel, tile-granular
overlap); and ``replicated`` for decode where tokens are replicated across
the EP axis.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, LayerSpec
from repro.core import moe as moe_lib
from repro.models import layers as L

Params = dict

__all__ = [
    "init", "forward", "init_caches", "decode_step", "encode",
    "moe_cfg_of", "ModelFns", "lm_loss",
]


def moe_cfg_of(
    cfg: ArchConfig, ep_axis: str = "model",
    token_axes: tuple[str, ...] = ("data", "model"),
) -> moe_lib.MoEConfig:
    return moe_lib.MoEConfig(
        d_model=cfg.d_model,
        d_ff=cfg.expert_ff,
        n_experts=cfg.n_experts,
        top_k=cfg.top_k,
        capacity_factor=cfg.capacity_factor,
        dtype=cfg.jdtype,
        ep_axis=ep_axis,
        token_axes=tuple(token_axes),
    )


# --------------------------------------------------------------------------
# per-slot layer init / fwd / step
# --------------------------------------------------------------------------


def _init_slot(key, cfg: ArchConfig, spec: LayerSpec) -> Params:
    ks = iter(jax.random.split(key, 8))
    p: Params = {}
    if spec.mixer in ("attn", "attn_local"):
        p["norm1"] = L.init_rms(cfg.d_model)
        p["mixer"] = L.init_attention(next(ks), cfg)
    elif spec.mixer == "rglru":
        p["norm1"] = L.init_rms(cfg.d_model)
        p["mixer"] = L.init_rglru(next(ks), cfg)
    elif spec.mixer == "ssd":
        p["norm1"] = L.init_rms(cfg.d_model)
        p["mixer"] = L.init_ssd(next(ks), cfg)
    if spec.cross_attn:
        p["norm_x"] = L.init_rms(cfg.d_model)
        p["xattn"] = L.init_attention(next(ks), cfg)
    if spec.ffn == "mlp":
        p["norm2"] = L.init_rms(cfg.d_model)
        p["ffn"] = L.init_mlp(next(ks), cfg)
    elif spec.ffn == "moe":
        p["norm2"] = L.init_rms(cfg.d_model)
        p["ffn"] = moe_lib.init_moe(next(ks), moe_cfg_of(cfg))
    return p


def _slot_fwd(
    p: Params, cfg: ArchConfig, spec: LayerSpec, x: jax.Array, *,
    positions, memory, causal: bool, moe_backend: str, mesh,
    return_cache: bool, moe_token_axes: tuple = ("data", "model"),
    cache_len: int | None = None,
):
    cache: Params = {}
    B, T, H = x.shape
    if spec.mixer in ("attn", "attn_local"):
        window = spec.window if spec.mixer == "attn_local" else 0
        h = L.rms_norm(p["norm1"], x, cfg.norm_eps)
        a = L.attention_fwd(
            p["mixer"], cfg, h, positions=positions, window=window,
            causal=causal,
        )
        x = x + a
        if return_cache:
            hd = cfg.hdim
            k = L._split_heads(
                h @ p["mixer"]["wk"].astype(h.dtype), cfg.n_kv_heads, hd
            )
            v = L._split_heads(
                h @ p["mixer"]["wv"].astype(h.dtype), cfg.n_kv_heads, hd
            )
            k = L.rope(k, positions, cfg.rope_theta)
            if window > 0:
                # Ring buffer sized exactly to the window so decode's
                # slot = pos % window indexing continues seamlessly.
                S = window
                tail = min(T, S)
                pos_tail = jnp.arange(T - tail, T)
                slots = jnp.mod(pos_tail, S)
                ck = jnp.zeros((B, S) + k.shape[2:], k.dtype)
                cv = jnp.zeros((B, S) + v.shape[2:], v.dtype)
                ck = ck.at[:, slots].set(k[:, T - tail:])
                cv = cv.at[:, slots].set(v[:, T - tail:])
                cache = {"k": ck, "v": cv}
            else:
                # Pad to cache_len so decode can append past the prompt.
                S = max(cache_len or T, T)
                pad = S - T
                ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                cache = {"k": ck, "v": cv}
    elif spec.mixer == "rglru":
        h = L.rms_norm(p["norm1"], x, cfg.norm_eps)
        if return_cache:
            y, cache = _rglru_fwd_cache(p["mixer"], cfg, h)
        else:
            y = L.rglru_fwd(p["mixer"], cfg, h)
        x = x + y
    elif spec.mixer == "ssd":
        h = L.rms_norm(p["norm1"], x, cfg.norm_eps)
        if return_cache:
            y, cache = _ssd_fwd_cache(p["mixer"], cfg, h)
        else:
            y = L.ssd_fwd(p["mixer"], cfg, h)
        x = x + y
    if spec.cross_attn:
        h = L.rms_norm(p["norm_x"], x, cfg.norm_eps)
        x = x + L.attention_fwd(
            p["xattn"], cfg, h, positions=positions, memory=memory
        )
    if spec.ffn == "mlp":
        h = L.rms_norm(p["norm2"], x, cfg.norm_eps)
        x = x + L.mlp_fwd(p["ffn"], h)
    elif spec.ffn == "moe":
        h = L.rms_norm(p["norm2"], x, cfg.norm_eps)
        flat = h.reshape(B * T, H)
        out = moe_lib.moe_apply(
            p["ffn"], moe_cfg_of(cfg, token_axes=moe_token_axes), flat,
            backend=moe_backend, mesh=mesh,
        )
        x = x + out.reshape(B, T, H)
    return x, cache


def _rglru_fwd_cache(p, cfg, h):
    y = L.rglru_fwd(p, cfg, h)
    # Recover final hidden state by replaying the scan tail cheaply: the
    # associative scan's last element is h_T; recompute from y is not
    # possible (y is post-projection), so run the gate path once more.
    xc = L._conv1d_fwd({"conv_w": p["conv_w"], "conv_b": p["conv_b"]}, h)
    i, log_a = L._rglru_gates(p, cfg, xc)
    gated = (
        jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-6))
        * (i * xc).astype(jnp.float32)
    )

    def combine(c1, c2):
        a1, h1 = c1
        a2, h2 = c2
        return a1 + a2, h1 * jnp.exp(a2) + h2

    _, hs = jax.lax.associative_scan(combine, (log_a, gated), axis=1)
    K = cfg.conv_kernel
    cache = {"h": hs[:, -1], "conv": h[:, -(K - 1):]}
    return y, cache


def _ssd_fwd_cache(p, cfg, h):
    # Run full fwd, then recompute the final state with a single pass over
    # the last chunk boundary — for simplicity we recompute the state by
    # scanning decay-weighted contributions (O(T) einsum, no materialized
    # sequence state).
    y = L.ssd_fwd(p, cfg, h)
    B, T, H = h.shape
    nh, dh, N = L._ssd_dims(cfg)
    x_pre, z, bmat, cmat, dt = L._ssd_proj(p, cfg, h)
    x = L._conv1d_fwd({"conv_w": p["conv_w"], "conv_b": p["conv_b"]}, x_pre)
    xs = jax.nn.silu(x)
    xh = xs.reshape(B, T, nh, dh).astype(jnp.float32)
    a = -jnp.exp(p["a_log"])
    la = dt * a                                       # (B, T, nh)
    cum = jnp.cumsum(la, axis=1)
    total = cum[:, -1]                                # (B, nh)
    w = jnp.exp(total[:, None] - cum) * dt            # (B, T, nh)
    bm = bmat.astype(jnp.float32)                     # (B, T, N)
    state = jnp.einsum("bthn,bthd->bhnd",
                       bm[:, :, None, :] * w[..., None], xh)
    K = cfg.conv_kernel
    # conv cache holds the *pre-conv* projected inputs (what ssd_step sees).
    cache = {"s": state, "conv": x_pre[:, -(K - 1):]}
    return y, cache


def _slot_step(
    p: Params, cfg: ArchConfig, spec: LayerSpec, x_t: jax.Array,
    cache: Params, pos, *, memory, moe_backend: str, mesh,
    moe_token_axes: tuple = ("data", "model"),
):
    B = x_t.shape[0]
    if spec.mixer in ("attn", "attn_local"):
        window = spec.window if spec.mixer == "attn_local" else 0
        h = L.rms_norm(p["norm1"], x_t, cfg.norm_eps)
        a, cache = L.attention_step(
            p["mixer"], cfg, h, cache, pos, window=window
        )
        x_t = x_t + a
    elif spec.mixer == "rglru":
        h = L.rms_norm(p["norm1"], x_t, cfg.norm_eps)
        y, cache = L.rglru_step(p["mixer"], cfg, h, cache, pos)
        x_t = x_t + y
    elif spec.mixer == "ssd":
        h = L.rms_norm(p["norm1"], x_t, cfg.norm_eps)
        y, cache = L.ssd_step(p["mixer"], cfg, h, cache, pos)
        x_t = x_t + y
    if spec.cross_attn:
        h = L.rms_norm(p["norm_x"], x_t, cfg.norm_eps)
        a, _ = L.attention_step(
            p["xattn"], cfg, h, {}, pos, memory=memory
        )
        x_t = x_t + a
    if spec.ffn == "mlp":
        h = L.rms_norm(p["norm2"], x_t, cfg.norm_eps)
        x_t = x_t + L.mlp_fwd(p["ffn"], h)
    elif spec.ffn == "moe":
        h = L.rms_norm(p["norm2"], x_t, cfg.norm_eps)
        flat = h.reshape(B, cfg.d_model)
        out = moe_lib.moe_apply(
            p["ffn"], moe_cfg_of(cfg, token_axes=moe_token_axes), flat,
            backend=moe_backend, mesh=mesh,
        )
        x_t = x_t + out.reshape(B, 1, cfg.d_model)
    return x_t, cache


def _slot_cache_init(cfg, spec, batch, max_len, dtype):
    if spec.mixer in ("attn", "attn_local"):
        window = spec.window if spec.mixer == "attn_local" else 0
        return L.init_kv_cache(cfg, batch, max_len, window, dtype)
    if spec.mixer == "rglru":
        return L.init_rglru_cache(cfg, batch, dtype)
    if spec.mixer == "ssd":
        return L.init_ssd_cache(cfg, batch, dtype)
    return {}


# --------------------------------------------------------------------------
# whole-model init / forward / decode
# --------------------------------------------------------------------------


def init(key, cfg: ArchConfig) -> Params:
    n_per, n_rem = cfg.n_periods()
    k_emb, k_per, k_rem, k_enc = jax.random.split(key, 4)
    params: Params = {"embed": L.init_embedding(k_emb, cfg)}
    slots = []
    for si, spec in enumerate(cfg.pattern):
        keys = jax.random.split(jax.random.fold_in(k_per, si), n_per)
        slots.append(jax.vmap(lambda k: _init_slot(k, cfg, spec))(keys))
    params["slots"] = slots
    params["rest"] = [
        _init_slot(jax.random.fold_in(k_rem, i), cfg, cfg.pattern[i])
        for i in range(n_rem)
    ]
    params["final_norm"] = L.init_rms(cfg.d_model)
    if cfg.n_encoder_layers:
        enc_spec = LayerSpec(mixer="attn", ffn="mlp")
        keys = jax.random.split(k_enc, cfg.n_encoder_layers)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: _init_slot(k, cfg, enc_spec))(keys),
            "norm": L.init_rms(cfg.d_model),
        }
    return params


def encode(params: Params, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """Encoder stack for enc-dec archs. frames: (B, Tm, H) stub embeddings."""
    spec = LayerSpec(mixer="attn", ffn="mlp")
    B, Tm, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(Tm), (B, Tm))

    def body(x, p):
        x, _ = _slot_fwd(
            p, cfg, spec, x, positions=positions, memory=None, causal=False,
            moe_backend="gathered", mesh=None, return_cache=False,
        )
        return x, None

    x, _ = jax.lax.scan(
        body, frames, params["encoder"]["layers"],
        unroll=bool(cfg.n_encoder_layers <= 2),
    )
    return L.rms_norm(params["encoder"]["norm"], x, cfg.norm_eps)


def forward(
    params: Params,
    cfg: ArchConfig,
    embeds: jax.Array,                 # (B, T, H) input embeddings
    *,
    memory: jax.Array | None = None,
    moe_backend: str = "gathered",
    mesh=None,
    return_caches: bool = False,
    positions: jax.Array | None = None,
    moe_token_axes: tuple = ("data", "model"),
    remat: bool = False,
    cache_len: int | None = None,
    return_hidden: bool = False,
):
    """Full-sequence forward. Returns logits (B, T, V) [and decode caches]."""
    B, T, _ = embeds.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    n_per, n_rem = cfg.n_periods()
    x = embeds

    def period_body(x, slot_params):
        caches = []
        for si, spec in enumerate(cfg.pattern):
            x, c = _slot_fwd(
                slot_params[si], cfg, spec, x, positions=positions,
                memory=memory, causal=True, moe_backend=moe_backend,
                mesh=mesh, return_cache=return_caches,
                moe_token_axes=moe_token_axes, cache_len=cache_len,
            )
            caches.append(c)
        return x, tuple(caches)

    if remat:
        # Activation checkpointing at period granularity: backward recomputes
        # one period's activations instead of holding all of them.
        period_body = jax.checkpoint(
            period_body,
            policy=jax.checkpoint_policies.save_only_these_names(),
        )
    # Unroll short stacks: loop-free HLO makes XLA cost_analysis exact,
    # which the dry-run's two-point depth extrapolation relies on.
    x, stacked_caches = jax.lax.scan(
        period_body, x, tuple(params["slots"]), unroll=bool(n_per <= 2)
    )

    rest_caches = []
    for i in range(n_rem):
        x, c = _slot_fwd(
            params["rest"][i], cfg, cfg.pattern[i], x, positions=positions,
            memory=memory, causal=True, moe_backend=moe_backend, mesh=mesh,
            return_cache=return_caches, moe_token_axes=moe_token_axes,
            cache_len=cache_len,
        )
        rest_caches.append(c)

    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x
    logits = L.unembed(params["embed"], x)
    if return_caches:
        return logits, {"slots": list(stacked_caches), "rest": rest_caches}
    return logits


def init_caches(cfg: ArchConfig, batch: int, max_len: int, dtype) -> Params:
    n_per, n_rem = cfg.n_periods()
    slots = []
    for spec in cfg.pattern:
        one = _slot_cache_init(cfg, spec, batch, max_len, dtype)
        slots.append(
            jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a, (n_per,) + a.shape
                ).copy() if n_per else a,
                one,
            )
        )
    rest = [
        _slot_cache_init(cfg, cfg.pattern[i], batch, max_len, dtype)
        for i in range(n_rem)
    ]
    return {"slots": slots, "rest": rest}


def decode_step(
    params: Params,
    cfg: ArchConfig,
    embeds_t: jax.Array,               # (B, 1, H)
    caches: Params,
    pos,                               # scalar int32 current position
    *,
    memory: jax.Array | None = None,
    moe_backend: str = "gathered",
    mesh=None,
    moe_token_axes: tuple = ("data", "model"),
):
    """One decode step. Returns (logits (B, V), new caches)."""
    x = embeds_t

    def period_body(x, inp):
        slot_params, slot_caches = inp
        new_caches = []
        for si, spec in enumerate(cfg.pattern):
            x, c = _slot_step(
                slot_params[si], cfg, spec, x, slot_caches[si], pos,
                memory=memory, moe_backend=moe_backend, mesh=mesh,
                moe_token_axes=moe_token_axes,
            )
            new_caches.append(c)
        return x, tuple(new_caches)

    n_per, _ = cfg.n_periods()
    x, new_slot_caches = jax.lax.scan(
        period_body, x, (tuple(params["slots"]), tuple(caches["slots"])),
        unroll=bool(n_per <= 2),
    )
    new_rest = []
    for i, p in enumerate(params["rest"]):
        x, c = _slot_step(
            p, cfg, cfg.pattern[i], x, caches["rest"][i], pos,
            memory=memory, moe_backend=moe_backend, mesh=mesh,
            moe_token_axes=moe_token_axes,
        )
        new_rest.append(c)
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x)[:, 0]
    return logits, {"slots": list(new_slot_caches), "rest": new_rest}


def lm_loss(
    params: Params, cfg: ArchConfig, tokens: jax.Array, labels: jax.Array,
    *, moe_backend: str = "gathered", mesh=None,
    extra_embeds: jax.Array | None = None,
    memory: jax.Array | None = None,
    moe_token_axes: tuple = ("data", "model"),
    remat: bool = True,
) -> jax.Array:
    """Next-token cross-entropy. tokens/labels: (B, T).

    With ``cfg.loss_chunk > 0`` the unembed + softmax run one token-chunk
    at a time inside a scan, so the (T, vocab) f32 logits tensor — the
    dominant HBM term for 256K-vocab models — is never materialized
    (§Perf lever).
    """
    x = L.embed(params["embed"], tokens, cfg.jdtype)
    if extra_embeds is not None:                 # VLM: image-token prefix
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    n_img = 0 if extra_embeds is None else extra_embeds.shape[1]

    if cfg.loss_chunk <= 0:
        logits = forward(
            params, cfg, x, memory=memory, moe_backend=moe_backend,
            mesh=mesh, moe_token_axes=moe_token_axes, remat=remat,
        )
        if n_img:
            logits = logits[:, n_img:]
        logits = logits.astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        return -jnp.mean(ll)

    hid = forward(
        params, cfg, x, memory=memory, moe_backend=moe_backend, mesh=mesh,
        moe_token_axes=moe_token_axes, remat=remat, return_hidden=True,
    )
    if n_img:
        hid = hid[:, n_img:]
    B, T, H = hid.shape
    chunk = max(1, min(cfg.loss_chunk, T))
    nchunks = max(1, T // chunk)
    chunk = T // nchunks
    hc = hid[:, : nchunks * chunk].reshape(B, nchunks, chunk, H)
    lc = labels[:, : nchunks * chunk].reshape(B, nchunks, chunk)

    def chunk_loss(carry, inp):
        hcb, lcb = inp                            # (B, chunk, H), (B, chunk)
        logits = L.unembed(params["embed"], hcb)  # f32, (B, chunk, V)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, lcb[..., None], axis=-1)[..., 0]
        return carry - jnp.sum(ll), None

    total, _ = jax.lax.scan(
        chunk_loss, jnp.zeros((), jnp.float32),
        (hc.transpose(1, 0, 2, 3), lc.transpose(1, 0, 2)),
    )
    tail = T - nchunks * chunk
    if tail:
        logits = L.unembed(params["embed"], hid[:, -tail:])
        logp = jax.nn.log_softmax(logits, axis=-1)
        total = total - jnp.sum(jnp.take_along_axis(
            logp, labels[:, -tail:, None], axis=-1))
    return total / (B * T)


class ModelFns(NamedTuple):
    init: Any
    forward: Any
    decode_step: Any
    init_caches: Any
    loss: Any
    encode: Any
