"""Family-aware model API: batch schema, loss, decode entry points.

The PeriodicDecoder implements all families; this module owns the
per-family *batch schema* (what `input_specs()` must provide) and glue:

  dense/moe/ssm/hybrid : {tokens (B,T), labels (B,T)}
  audio (whisper)      : {frames (B,Tm,H) stub embeddings, tokens, labels}
  vlm (llava)          : {img_embeds (B,Ti,H) stub embeddings, tokens, labels}
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import transformer as T

__all__ = ["Model", "build_model"]


class Model:
    """Thin family-aware facade over the PeriodicDecoder."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # -- init ------------------------------------------------------------
    def init(self, key) -> dict:
        return T.init(key, self.cfg)

    # -- training --------------------------------------------------------
    def loss(self, params, batch, *, moe_backend="gathered", mesh=None,
             moe_token_axes=("data", "model")):
        cfg = self.cfg
        kw = dict(moe_backend=moe_backend, mesh=mesh,
                  moe_token_axes=moe_token_axes)
        if cfg.family == "audio":
            memory = T.encode(params, cfg, batch["frames"].astype(cfg.jdtype))
            return T.lm_loss(
                params, cfg, batch["tokens"], batch["labels"],
                memory=memory, **kw,
            )
        if cfg.family == "vlm":
            return T.lm_loss(
                params, cfg, batch["tokens"], batch["labels"],
                extra_embeds=batch["img_embeds"], **kw,
            )
        return T.lm_loss(
            params, cfg, batch["tokens"], batch["labels"], **kw,
        )

    # -- serving ---------------------------------------------------------
    def prefill(self, params, batch, *, moe_backend="gathered", mesh=None,
                moe_token_axes=("data", "model"), max_len: int | None = None):
        """Full-context forward producing logits + decode caches.

        ``max_len`` pads full-attention caches so decode can append beyond
        the prompt (window caches are ring-sized already)."""
        cfg = self.cfg
        memory = None
        x = L.embed(params["embed"], batch["tokens"], cfg.jdtype)
        if cfg.family == "audio":
            memory = T.encode(params, cfg, batch["frames"].astype(cfg.jdtype))
        if cfg.family == "vlm":
            x = jnp.concatenate(
                [batch["img_embeds"].astype(cfg.jdtype), x], axis=1
            )
        logits, caches = T.forward(
            params, cfg, x, memory=memory, moe_backend=moe_backend,
            mesh=mesh, return_caches=True, moe_token_axes=moe_token_axes,
            cache_len=max_len,
        )
        return logits, caches, memory

    def init_caches(self, batch: int, max_len: int):
        return T.init_caches(self.cfg, batch, max_len, self.cfg.jdtype)

    def decode_step(
        self, params, tokens_t, caches, pos, *,
        memory=None, moe_backend="gathered", mesh=None,
        moe_token_axes=("data", "model"),
    ):
        """tokens_t: (B, 1) int32 -> (logits (B, V), new caches)."""
        cfg = self.cfg
        x = L.embed(params["embed"], tokens_t, cfg.jdtype)
        return T.decode_step(
            params, cfg, x, caches, pos, memory=memory,
            moe_backend=moe_backend, mesh=mesh,
            moe_token_axes=moe_token_axes,
        )

    # -- misc --------------------------------------------------------------
    def param_count(self, params) -> int:
        return sum(x.size for x in jax.tree.leaves(params))


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
