"""Primitive neural blocks shared by the model zoo.

Pure functions over parameter pytrees (nested dicts of arrays) — no module
framework.  Every mixer implements three entry points used by the decoder:

  ``init(key, cfg)``                         -> params
  ``fwd(params, cfg, x, ...)``               -> y                (train/prefill)
  ``step(params, cfg, x_t, cache, pos)``     -> (y_t, new_cache) (decode)

Attention defaults to the XLA path (portable: CPU dry-run, TPU); the Pallas
flash-attention / SSD kernels in ``repro.kernels`` are the TPU fast path and
are validated against the same math in tests.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec

__all__ = [
    "rms_norm", "init_rms", "rope",
    "init_attention", "attention_fwd", "attention_step", "init_kv_cache",
    "init_mlp", "mlp_fwd",
    "init_rglru", "rglru_fwd", "rglru_step", "init_rglru_cache",
    "init_ssd", "ssd_fwd", "ssd_step", "init_ssd_cache",
    "init_embedding", "embed", "unembed",
]

Params = dict


def _dense_init(key, shape, scale_axis=0):
    scale = 1.0 / math.sqrt(shape[scale_axis])
    return jax.random.normal(key, shape, dtype=jnp.float32) * scale


# --------------------------------------------------------------------------
# norm / rope / embedding
# --------------------------------------------------------------------------


def init_rms(d: int) -> Params:
    return {"scale": jnp.ones((d,), dtype=jnp.float32)}


def rms_norm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["scale"]).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., T, n_heads, head_dim); positions: (..., T)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs     # (..., T, half)
    ang = ang[..., None, :]                                     # head axis
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def init_embedding(key, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"tok": _dense_init(k1, (cfg.vocab, cfg.d_model), 1)}
    if not cfg.tie_embeddings:
        p["head"] = _dense_init(k2, (cfg.d_model, cfg.vocab))
    return p


def embed(p: Params, tokens: jax.Array, dtype) -> jax.Array:
    return p["tok"].astype(dtype)[tokens]


def unembed(p: Params, x: jax.Array) -> jax.Array:
    w = p.get("head")
    if w is None:
        w = p["tok"].T
    # f32 accumulation without materializing an f32 copy of the (possibly
    # vocab-sharded, bf16) embedding table.
    return jnp.einsum(
        "...h,hv->...v", x.astype(w.dtype), w,
        preferred_element_type=jnp.float32,
    )


# --------------------------------------------------------------------------
# attention (GQA, optional sliding window, optional cross-attention)
# --------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig) -> Params:
    H, hd = cfg.d_model, cfg.hdim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense_init(ks[0], (H, nq * hd)),
        "wk": _dense_init(ks[1], (H, nkv * hd)),
        "wv": _dense_init(ks[2], (H, nkv * hd)),
        "wo": _dense_init(ks[3], (nq * hd, H)),
    }


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _sdpa(q, k, v, mask) -> jax.Array:
    """q: (B,Tq,nq,hd) k,v: (B,Tk,nkv,hd); GQA via head grouping."""
    B, Tq, nq, hd = q.shape
    nkv = k.shape[2]
    g = nq // nkv
    # f32 accumulation via preferred_element_type: never materializes an
    # f32 copy of K/V (a cache-sized cast dominated decode HBM traffic).
    qf = q.reshape(B, Tq, nkv, g, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k,
                   preferred_element_type=jnp.float32)
    s = s * (hd ** -0.5)
    if mask is not None:
        s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Tq, nq, hd).astype(q.dtype)


def _sdpa_chunked(q, k, v, *, causal: bool, window: int, chunk: int,
                  q_offset: int = 0) -> jax.Array:
    """Blockwise online-softmax attention in pure jnp (flash-style).

    Never materializes the (Tq, Tk) score matrix: scans KV chunks carrying
    running (max, normalizer, accumulator).  This is the XLA twin of
    ``kernels/flash_attention.py`` for hosts/backends where the Pallas
    kernel isn't available; on TPU the kernel is the fast path.
    """
    B, Tq, nq, hd = q.shape
    Tk = k.shape[1]
    nkv = k.shape[2]
    g = nq // nkv
    nchunks = max(1, Tk // chunk)
    chunk = Tk // nchunks
    qf = q.astype(jnp.float32).reshape(B, Tq, nkv, g, hd) * (hd ** -0.5)
    kc = k.astype(jnp.float32).reshape(B, nchunks, chunk, nkv, hd)
    vc = v.astype(jnp.float32).reshape(B, nchunks, chunk, nkv, hd)
    iq = q_offset + jnp.arange(Tq)

    def step(carry, inp):
        m, l, acc = carry
        kcb, vcb, c_idx = inp
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kcb)
        ik = c_idx * chunk + jnp.arange(chunk)
        mask = iq[:, None] >= ik[None, :] if causal else jnp.ones(
            (Tq, chunk), bool)
        if window > 0:
            mask &= (iq[:, None] - ik[None, :]) < window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, vcb)
        return (m_new, l, acc), None

    m0 = jnp.full((B, nkv, g, Tq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, nkv, g, Tq), jnp.float32)
    a0 = jnp.zeros((B, nkv, g, Tq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
         jnp.arange(nchunks)),
    )
    out = acc / jnp.where(l == 0.0, 1.0, l)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Tq, nq, hd)
    return out.astype(q.dtype)


def _causal_mask(Tq: int, Tk: int, window: int) -> jax.Array:
    iq = jnp.arange(Tq)[:, None] + (Tk - Tq)
    ik = jnp.arange(Tk)[None, :]
    m = iq >= ik
    if window > 0:
        m &= (iq - ik) < window
    return m[None]  # (1, Tq, Tk)


def attention_fwd(
    p: Params, cfg: ArchConfig, x: jax.Array, *,
    positions: jax.Array, window: int = 0, causal: bool = True,
    memory: jax.Array | None = None,
) -> jax.Array:
    """Full-sequence attention. x: (B, T, H). memory: (B, Tm, H) for cross."""
    B, T, _ = x.shape
    hd = cfg.hdim
    q = _split_heads(x @ p["wq"].astype(x.dtype), cfg.n_heads, hd)
    src = memory if memory is not None else x
    k = _split_heads(src @ p["wk"].astype(x.dtype), cfg.n_kv_heads, hd)
    v = _split_heads(src @ p["wv"].astype(x.dtype), cfg.n_kv_heads, hd)
    if memory is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        if cfg.attn_chunk > 0 and T >= 2 * cfg.attn_chunk:
            o = _sdpa_chunked(q, k, v, causal=causal, window=window,
                              chunk=cfg.attn_chunk)
            return o.reshape(B, T, -1) @ p["wo"].astype(x.dtype)
        mask = _causal_mask(T, T, window) if causal else None
    else:
        mask = None
    o = _sdpa(q, k, v, mask)
    return o.reshape(B, T, -1) @ p["wo"].astype(x.dtype)


def init_kv_cache(
    cfg: ArchConfig, batch: int, max_len: int, window: int, dtype
) -> Params:
    S = min(max_len, window) if window > 0 else max_len
    shape = (batch, S, cfg.n_kv_heads, cfg.hdim)
    return {
        "k": jnp.zeros(shape, dtype=dtype),
        "v": jnp.zeros(shape, dtype=dtype),
    }


def attention_step(
    p: Params, cfg: ArchConfig, x_t: jax.Array, cache: Params,
    pos: jax.Array, *, window: int = 0, memory: jax.Array | None = None,
) -> tuple[jax.Array, Params]:
    """One decode step. x_t: (B, 1, H); pos: scalar int (current index)."""
    B = x_t.shape[0]
    hd = cfg.hdim
    q = _split_heads(x_t @ p["wq"].astype(x_t.dtype), cfg.n_heads, hd)
    if memory is not None:
        # Cross-attention: static memory, no cache update.
        k = _split_heads(memory @ p["wk"].astype(x_t.dtype), cfg.n_kv_heads, hd)
        v = _split_heads(memory @ p["wv"].astype(x_t.dtype), cfg.n_kv_heads, hd)
        o = _sdpa(q, k, v, None)
        return o.reshape(B, 1, -1) @ p["wo"].astype(x_t.dtype), cache

    posv = jnp.full((B, 1), pos, dtype=jnp.int32)
    q = rope(q, posv, cfg.rope_theta)
    k_t = _split_heads(x_t @ p["wk"].astype(x_t.dtype), cfg.n_kv_heads, hd)
    v_t = _split_heads(x_t @ p["wv"].astype(x_t.dtype), cfg.n_kv_heads, hd)
    k_t = rope(k_t, posv, cfg.rope_theta)

    S = cache["k"].shape[1]
    slot = jnp.mod(pos, S) if window > 0 else pos
    # Masked-blend update instead of dynamic_update_slice: elementwise ops
    # keep the cache's sequence sharding intact (GSPMD replicates a whole
    # cache shard to reshard an in-place update on a sharded dim — tens of
    # GB per layer for 32K-context serving).
    onehot = (jnp.arange(S) == slot)[None, :, None, None]
    k = jnp.where(onehot, k_t.astype(cache["k"].dtype), cache["k"])
    v = jnp.where(onehot, v_t.astype(cache["v"].dtype), cache["v"])

    # Validity: ring buffer holds the last min(pos+1, S) entries.
    idx = jnp.arange(S)
    if window > 0:
        valid = (idx <= pos) if True else None
        # entry i holds absolute position with same residue; valid if within
        # the last `window` positions and <= pos.
        abs_pos = pos - jnp.mod(pos - idx, S)
        valid = (abs_pos >= 0) & (abs_pos >= pos - (S - 1))
    else:
        valid = idx <= pos
    mask = valid[None, None, :]                     # (1, 1, S)
    nkv, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    qf = q.reshape(B, 1, nkv, g, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k,
                   preferred_element_type=jnp.float32)
    s = s * (hd ** -0.5)
    s = jnp.where(mask[:, None, None], s, -1e30)
    pattn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", pattn.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, -1).astype(x_t.dtype)
    out = o @ p["wo"].astype(x_t.dtype)
    return out, {"k": k, "v": v}


# --------------------------------------------------------------------------
# gated MLP
# --------------------------------------------------------------------------


def init_mlp(key, cfg: ArchConfig) -> Params:
    H, F = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": _dense_init(k1, (H, F)),
        "w3": _dense_init(k3, (H, F)),
        "w2": _dense_init(k2, (F, H)),
    }


def mlp_fwd(p: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["w1"].astype(x.dtype)) * (x @ p["w3"].astype(x.dtype))
    return h @ p["w2"].astype(x.dtype)


# --------------------------------------------------------------------------
# RG-LRU (RecurrentGemma) with short conv
# --------------------------------------------------------------------------


def init_rglru(key, cfg: ArchConfig) -> Params:
    H = cfg.d_model
    ks = jax.random.split(key, 5)
    return {
        "conv_w": _dense_init(ks[0], (cfg.conv_kernel, H), 0) * 0.1,
        "conv_b": jnp.zeros((H,), dtype=jnp.float32),
        "wr": _dense_init(ks[1], (H, H)),
        "wi": _dense_init(ks[2], (H, H)),
        # a-parameter init so decay ~ U[0.9, 0.999] (Griffin appendix):
        # softplus(a_raw) = (-log u)^(1/c)  =>  a = exp(-c * softplus * r).
        "a_raw": jnp.log(
            jnp.expm1(
                (-jnp.log(jax.random.uniform(
                    ks[3], (H,), minval=0.9, maxval=0.999
                ))) ** (1.0 / cfg.rglru_c)
            )
        ).astype(jnp.float32),
        "wo": _dense_init(ks[4], (H, H)),
    }


def _rglru_gates(p, cfg, x):
    r = jax.nn.sigmoid(x @ p["wr"].astype(x.dtype))
    i = jax.nn.sigmoid(x @ p["wi"].astype(x.dtype))
    log_a = (
        -cfg.rglru_c
        * jax.nn.softplus(p["a_raw"]).astype(jnp.float32)
        * r.astype(jnp.float32)
    )                                                  # (B, T, H), <= 0
    return i, log_a


def _conv1d_fwd(p, x):
    """Causal depthwise conv over time. x: (B, T, H)."""
    K = p["conv_w"].shape[0]
    pads = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(
        pads[:, i : i + x.shape[1], :] * p["conv_w"][i].astype(x.dtype)
        for i in range(K)
    )
    return out + p["conv_b"].astype(x.dtype)


def rglru_fwd(p: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Full-sequence RG-LRU via associative scan. x: (B, T, H)."""
    xc = _conv1d_fwd(p, x)
    i, log_a = _rglru_gates(p, cfg, xc)
    gated = (
        jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-6))
        * (i * xc).astype(jnp.float32)
    )

    def combine(c1, c2):
        a1, h1 = c1
        a2, h2 = c2
        return a1 + a2, h1 * jnp.exp(a2) + h2

    _, h = jax.lax.associative_scan(
        combine, (log_a, gated), axis=1
    )
    return (h.astype(x.dtype)) @ p["wo"].astype(x.dtype)


def init_rglru_cache(cfg: ArchConfig, batch: int, dtype) -> Params:
    H = cfg.d_model
    return {
        "h": jnp.zeros((batch, H), dtype=jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, H), dtype=dtype),
    }


def rglru_step(
    p: Params, cfg: ArchConfig, x_t: jax.Array, cache: Params, pos
) -> tuple[jax.Array, Params]:
    """x_t: (B, 1, H)."""
    K = p["conv_w"].shape[0]
    hist = jnp.concatenate([cache["conv"], x_t], axis=1)   # (B, K, H)
    xc = jnp.einsum(
        "bkh,kh->bh", hist.astype(jnp.float32), p["conv_w"]
    ) + p["conv_b"]
    xc = xc[:, None, :].astype(x_t.dtype)                   # (B, 1, H)
    i, log_a = _rglru_gates(p, cfg, xc)
    a = jnp.exp(log_a[:, 0])                                # (B, H)
    gated = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-6)) * (
        (i * xc)[:, 0].astype(jnp.float32)
    )
    h = cache["h"] * a + gated
    out = (h[:, None, :].astype(x_t.dtype)) @ p["wo"].astype(x_t.dtype)
    return out, {"h": h, "conv": hist[:, 1:]}


# --------------------------------------------------------------------------
# SSD (Mamba-2)
# --------------------------------------------------------------------------


def _ssd_dims(cfg: ArchConfig) -> tuple[int, int, int]:
    dh = cfg.ssm_head_dim
    inner = cfg.ssm_expand * cfg.d_model
    nh = max(1, inner // dh)
    return nh, dh, cfg.ssm_state


def init_ssd(key, cfg: ArchConfig) -> Params:
    H = cfg.d_model
    nh, dh, N = _ssd_dims(cfg)
    inner = nh * dh
    ks = jax.random.split(key, 6)
    return {
        "in_x": _dense_init(ks[0], (H, inner)),
        "in_z": _dense_init(ks[1], (H, inner)),          # output gate
        "in_b": _dense_init(ks[2], (H, N)),
        "in_c": _dense_init(ks[3], (H, N)),
        "in_dt": _dense_init(ks[4], (H, nh)),
        "dt_bias": jnp.zeros((nh,), dtype=jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "d_skip": jnp.ones((nh,), dtype=jnp.float32),
        "conv_w": _dense_init(ks[5], (cfg.conv_kernel, inner), 0) * 0.1,
        "conv_b": jnp.zeros((inner,), dtype=jnp.float32),
        "out": _dense_init(ks[5], (inner, H)),
    }


def _ssd_proj(p, cfg, u):
    nh, dh, N = _ssd_dims(cfg)
    x = u @ p["in_x"].astype(u.dtype)                   # (B, T, inner)
    z = u @ p["in_z"].astype(u.dtype)
    bmat = u @ p["in_b"].astype(u.dtype)                # (B, T, N)
    cmat = u @ p["in_c"].astype(u.dtype)
    dt = jax.nn.softplus(
        (u @ p["in_dt"].astype(u.dtype)).astype(jnp.float32) + p["dt_bias"]
    )                                                   # (B, T, nh)
    return x, z, bmat, cmat, dt


def ssd_fwd(p: Params, cfg: ArchConfig, u: jax.Array, *,
            chunk: int = 128) -> jax.Array:
    """Full-sequence SSD via chunked jnp (same math as kernels/ssd_scan)."""
    B, T, H = u.shape
    nh, dh, N = _ssd_dims(cfg)
    x, z, bmat, cmat, dt = _ssd_proj(p, cfg, u)
    x = _conv1d_fwd({"conv_w": p["conv_w"], "conv_b": p["conv_b"]}, x)
    x = jax.nn.silu(x)
    xh = x.reshape(B, T, nh, dh)
    a = -jnp.exp(p["a_log"])                            # (nh,), negative

    Lc = min(chunk, T)
    if T % Lc:
        Lc = math.gcd(T, Lc) or 1
    nchunks = T // Lc

    # Broadcast B/C across heads (mamba2 shares B,C per head-group; G=1).
    bm = jnp.broadcast_to(bmat[:, :, None, :], (B, T, nh, N))
    cm = jnp.broadcast_to(cmat[:, :, None, :], (B, T, nh, N))

    def reshape_chunks(t):  # (B, T, ...) -> (B, nchunks, Lc, ...)
        return t.reshape((B, nchunks, Lc) + t.shape[2:])

    xc = reshape_chunks(xh).astype(jnp.float32)
    bc = reshape_chunks(bm).astype(jnp.float32)
    cc = reshape_chunks(cm).astype(jnp.float32)
    dtc = reshape_chunks(dt)                            # (B, nc, Lc, nh)

    la = dtc * a                                        # (B, nc, Lc, nh)
    cum = jnp.cumsum(la, axis=2)

    def chunk_step(state, inp):
        xcb, bcb, ccb, dtb, lab, cumb = inp             # per-chunk slices
        # state: (B, nh, N, dh)
        y_inter = jnp.einsum("blhn,bhnd->blhd", ccb, state) * jnp.exp(
            cumb
        )[..., None]
        scores = jnp.einsum("blhn,bshn->bhls", ccb, bcb)
        Lcc = xcb.shape[1]
        mask = jnp.tril(jnp.ones((Lcc, Lcc), dtype=bool))
        # Mask the log-decay *before* exp: the upper triangle holds large
        # positive differences that would overflow and poison the masked
        # product with inf*0 = NaN.
        ldiff = (cumb.transpose(0, 2, 1)[:, :, :, None]
                 - cumb.transpose(0, 2, 1)[:, :, None, :])
        decay = jnp.exp(jnp.where(mask, ldiff, -jnp.inf))
        m = scores * decay * dtb.transpose(0, 2, 1)[:, :, None, :]
        y_intra = jnp.einsum("bhls,bshd->blhd", m, xcb)
        total = cumb[:, -1]                             # (B, nh)
        w = jnp.exp(total[:, None] - cumb) * dtb        # (B, Lc, nh)
        new_state = state * jnp.exp(total)[..., None, None] + jnp.einsum(
            "blhn,blhd->bhnd", bcb * w[..., None], xcb
        )
        return new_state, y_inter + y_intra

    s0 = jnp.zeros((B, nh, N, dh), dtype=jnp.float32)
    xs = (
        xc.transpose(1, 0, 2, 3, 4), bc.transpose(1, 0, 2, 3, 4),
        cc.transpose(1, 0, 2, 3, 4), dtc.transpose(1, 0, 2, 3),
        la.transpose(1, 0, 2, 3), cum.transpose(1, 0, 2, 3),
    )
    _, ys = jax.lax.scan(chunk_step, s0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, T, nh, dh)
    y = y + p["d_skip"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(B, T, nh * dh).astype(u.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out"].astype(u.dtype)


def init_ssd_cache(cfg: ArchConfig, batch: int, dtype) -> Params:
    nh, dh, N = _ssd_dims(cfg)
    inner = nh * dh
    return {
        "s": jnp.zeros((batch, nh, N, dh), dtype=jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, inner), dtype=dtype),
    }


def ssd_step(
    p: Params, cfg: ArchConfig, u_t: jax.Array, cache: Params, pos
) -> tuple[jax.Array, Params]:
    """One decode step. u_t: (B, 1, H)."""
    B = u_t.shape[0]
    nh, dh, N = _ssd_dims(cfg)
    x, z, bmat, cmat, dt = _ssd_proj(p, cfg, u_t)
    hist = jnp.concatenate([cache["conv"], x], axis=1)
    xc = jnp.einsum(
        "bkh,kh->bh", hist.astype(jnp.float32), p["conv_w"]
    ) + p["conv_b"]
    xc = jax.nn.silu(xc)                                  # (B, inner)
    xh = xc.reshape(B, nh, dh)
    a = -jnp.exp(p["a_log"])
    dt0 = dt[:, 0]                                        # (B, nh)
    decay = jnp.exp(dt0 * a)                              # (B, nh)
    bm = bmat[:, 0].astype(jnp.float32)                   # (B, N)
    cm = cmat[:, 0].astype(jnp.float32)
    s = cache["s"] * decay[..., None, None] + (
        dt0[..., None, None]
        * bm[:, None, :, None]
        * xh[:, :, None, :]
    )
    y = jnp.einsum("bn,bhnd->bhd", cm, s)
    y = y + p["d_skip"][:, None] * xh
    y = y.reshape(B, 1, nh * dh).astype(u_t.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out"].astype(u_t.dtype)
    return out, {"s": s, "conv": hist[:, 1:]}
