"""Perseus-TPU: production-grade JAX/Pallas reproduction of
"Eliminating Hidden Serialization in Multi-Node Megakernel Communication"
(Oh & Singh, CS.DC 2026).  See DESIGN.md for the system inventory."""

__version__ = "1.0.0"
