"""The paper's contribution: Perseus signaling protocol, calibrated
proxy/NIC transport simulator, and the expert-parallel MoE block."""

from repro.core.moe import MoEConfig, init_moe, moe_apply
from repro.core.routing import expert_capacity, topk_routing
from repro.core.signaling import (
    Schedule, ScheduleKind, Transfer, build_schedule, fence_count,
    moe_dispatch_transfers, optimal_group_size,
)
from repro.core.transport_sim import (
    IBGDA, IBRC, LIBFABRIC, NVLINK, TRANSPORTS,
    signaling_efficiency, simulate_forward, simulate_moe_layer,
    simulate_proxy,
)
