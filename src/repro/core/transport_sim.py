"""Discrete-event simulator of the proxy-based RDMA submission path.

This is the *performance half* of the reproduction (DESIGN.md §2): a
calibrated model of the GPU -> proxy FIFO -> NIC -> wire pipeline of
NVSHMEM-style device-initiated RDMA, faithful to §3 of the paper:

  * a single proxy thread drains one FIFO of work requests (WRs) in order,
    paying a fixed submission cost per WR;
  * a proxy FENCE blocks the proxy until every in-flight PUT on the channel
    has returned a *completion* from the NIC (``fi_cntr_wait`` /
    ``check_poll_avail``), and the drain cost grows with node count and
    message size (Fig. 5b);
  * a NIC-side fence flag (``FI_FENCE`` / ``IBV_SEND_FENCE``) instead defers
    the flagged WR inside the NIC until prior WRs on the *same QP* complete:
    the NIC pipeline stalls but the proxy keeps submitting (Fig. 2c);
  * on multi-QP transports, ordering only holds within a QP, so Perseus pins
    all WRs for a peer to ``qp = pe % num_qp`` (§5).

Calibration: the free constants in the ``LIBFABRIC`` / ``IBRC`` / ``IBGDA``
presets are fitted to the paper's measured anchors (Fig. 5b aggregate fence
times, Fig. 5a 2% signaling-efficiency collapse, Appendix A alpha/beta fits)
and every paper figure is re-derived from the *mechanism*, not hard-coded —
see ``benchmarks/`` for the per-figure drivers and ``tests/test_paper_claims``
for the tolerance bands.

Times are microseconds, sizes bytes, bandwidths GB/s (== bytes/us / 1e3).
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, Iterable, Sequence

from repro.core.signaling import (
    Op,
    OpKind,
    Schedule,
    ScheduleKind,
    Transfer,
    build_schedule,
    group_by_destination,
    moe_dispatch_transfers,
)

__all__ = [
    "TransportParams",
    "LIBFABRIC",
    "IBRC",
    "IBGDA",
    "NVLINK",
    "TRANSPORTS",
    "SimResult",
    "simulate_proxy",
    "signaling_efficiency",
    "GpuParams",
    "A100",
    "H100",
    "MoEModelSpec",
    "QWEN3_30B",
    "GPT_OSS_120B",
    "DEEPSEEK_V3",
    "LLAMA4_SCOUT",
    "PAPER_MODELS",
    "LayerResult",
    "simulate_moe_layer",
    "simulate_forward",
    "alltoall_transfers",
    "simulate_alltoall",
    "nccl_alltoall_latency",
    "fit_alpha_beta",
]


# --------------------------------------------------------------------------
# Transport parameterization
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransportParams:
    """Timing model of one device-initiated RDMA submission path."""

    name: str
    proxy_submit_us: float        # proxy cost to forward one WR to the NIC
    wire_GBps: float              # NIC egress bandwidth
    alpha_us: float               # one-way data latency (last byte -> visible)
    # Completion (ACK) latency seen by a *proxy fence*:
    #   ack(n_nodes, nbytes) = ack_base_us * n_nodes**ack_node_exp
    #                          + ack_bytes_frac(n) * nbytes / wire
    # The node exponent captures the destination-count tail of the drain
    # (§3.3 "a single fence's drain grows with node count"); the bytes term
    # captures the receiver-side PCIe write + ACK serialization.
    ack_base_us: float
    ack_node_exp: float
    ack_bytes_frac0: float
    ack_bytes_frac_node: float
    drain_poll_us: float          # software cost of one drain even when empty
    nic_fence_us: float           # NIC-side cost to honor a fence flag
    signal_wire_us: float         # wire occupancy of an 8B signal
    signal_submit_us: float = 0.25  # tiny inline WQE; cheaper than a PUT WR
    num_qp: int = 1
    gpu_submit_us: float = 0.0    # GPU-direct WQE submission (IBGDA)
    proxy: bool = True            # False => GPU-direct path
    sm_interference: float = 0.0  # compute slowdown from GPU-side submission
    # NIC-direct transports order put->signal inside a QP for free:
    inqp_ordering_free: bool = False

    def ack_us(self, n_nodes: int, nbytes: int) -> float:
        """Software-visible completion latency (what a *proxy drain* waits on).

        ``fi_cntr_wait`` / ``check_poll_avail`` sync a software counter with
        the NIC; the cost grows with fabric diameter / destination tail
        (node exponent) and with message size (receiver PCIe write + ACK).
        """
        frac = self.ack_bytes_frac0 + self.ack_bytes_frac_node * n_nodes
        n = max(1, n_nodes)
        if n <= 8:
            node_factor = n ** self.ack_node_exp
        else:
            # Fig. 5b measures 2-8 nodes; beyond that the dragonfly diameter
            # stops growing (3 hops worst case) and the tail saturates.
            node_factor = (8 ** self.ack_node_exp) * (n / 8) ** 0.45
        return (
            self.ack_base_us * node_factor
            + frac * nbytes / (self.wire_GBps * 1e3)
        )

    def hw_completion_us(self, nbytes: int) -> float:
        """Hardware-internal completion (what a *NIC fence flag* waits on).

        The NIC tracks prior-WR completion "through internal hardware
        registers rather than a software counter" (§4.2) — an ACK round trip,
        independent of node count and far cheaper than the software drain.
        """
        return 2.0 * self.alpha_us + 0.1 * nbytes / (self.wire_GBps * 1e3)

    def wire_us(self, nbytes: int) -> float:
        return nbytes / (self.wire_GBps * 1e3)


# Calibrated to Perlmutter measurements in the paper: Fig. 5b gives
# per-fence drain ~10us @2 nodes -> ~63us @8 nodes for 4KB messages
# (0.96ms and 6.1ms aggregate over 96 transfers) and ~36us -> ~96us for 1MB,
# which fixes (ack_base, ack_node_exp) = (3.97, 1.333) and the bytes
# fractions below.  200 Gb/s Slingshot-11 => 25 GB/s.
LIBFABRIC = TransportParams(
    name="libfabric",
    proxy_submit_us=1.0,
    wire_GBps=25.0,
    alpha_us=2.5,
    ack_base_us=3.97,
    ack_node_exp=1.333,
    ack_bytes_frac0=0.6,
    ack_bytes_frac_node=0.025,
    drain_poll_us=2.0,
    nic_fence_us=0.5,
    signal_wire_us=0.05,
    num_qp=1,
)

# ConnectX-7 IBRC: hardware CQ polling makes the fixed drain cheap
# ("alpha is inherently small (1-5 ms) because hardware completion queue
# polling is lightweight", App. A) but per-put fences stop cross-QP
# pipelining, inflating the effective per-byte cost (beta) by ~2.5x — the
# ack_bytes_frac=1.5 anchor reproduces the paper's "beta reduced by up to
# 60%" once Perseus restores pipelining.  InfiniBand NDR => 50 GB/s.
IBRC = TransportParams(
    name="ibrc",
    proxy_submit_us=0.7,
    wire_GBps=50.0,
    alpha_us=2.0,
    ack_base_us=1.8,
    ack_node_exp=0.6,
    ack_bytes_frac0=1.45,
    ack_bytes_frac_node=0.012,
    drain_poll_us=0.6,
    nic_fence_us=0.3,
    signal_wire_us=0.03,
    num_qp=4,
)

# IBGDA GPU-direct: no proxy; WQE submission burns SM cycles (§6.2), and
# in-QP ordering makes put-with-signal free of software fences.
IBGDA = TransportParams(
    name="ibgda",
    proxy_submit_us=0.0,
    wire_GBps=50.0,
    alpha_us=2.0,
    ack_base_us=1.8,
    ack_node_exp=0.6,
    ack_bytes_frac0=0.25,
    ack_bytes_frac_node=0.0,
    drain_poll_us=0.0,
    nic_fence_us=0.3,
    signal_wire_us=0.03,
    num_qp=1,
    gpu_submit_us=0.35,
    proxy=False,
    sm_interference=0.04,
    inqp_ordering_free=True,
)

# Intra-node NVLink: signals are hardware-coupled to the store, no proxy,
# near-linear scaling with concurrency (§3.1).
NVLINK = TransportParams(
    name="nvlink",
    proxy_submit_us=0.0,
    wire_GBps=300.0,
    alpha_us=1.5,
    ack_base_us=0.3,
    ack_node_exp=0.0,
    ack_bytes_frac0=0.05,
    ack_bytes_frac_node=0.0,
    drain_poll_us=0.0,
    nic_fence_us=0.0,
    signal_wire_us=0.01,
    num_qp=1,
    gpu_submit_us=0.1,
    proxy=False,
    inqp_ordering_free=True,
)

TRANSPORTS = {t.name: t for t in (LIBFABRIC, IBRC, IBGDA, NVLINK)}


# --------------------------------------------------------------------------
# Proxy / NIC event simulation
# --------------------------------------------------------------------------


@dataclasses.dataclass
class OpEvent:
    op: Op
    submit_t: float       # when the proxy (or GPU) forwarded the WR
    wire_start: float
    wire_end: float
    data_arrival: float   # payload visible at receiver
    completion: float     # completion observed back at the sender NIC/proxy
    proxy_stall: float    # proxy blocked time attributable to this op
    nic_stall: float      # NIC pipeline defer time attributable to this op


@dataclasses.dataclass
class SimResult:
    events: list[OpEvent]
    total_time: float              # all WRs complete + signals visible
    proxy_stall: float             # total proxy blocked time (fence drains)
    nic_stall: float               # total NIC defer time (fence flags)
    signal_visible: dict[int, float]   # tag -> receiver may consume tile
    data_arrival: dict[int, float]     # tag -> payload landed
    wire_busy: float               # total egress wire occupancy
    n_fences: int

    @property
    def overhead_fraction(self) -> float:
        """Fraction of total time not explained by wire occupancy (alpha/T)."""
        if self.total_time <= 0:
            return 0.0
        return max(0.0, 1.0 - self.wire_busy / self.total_time)


def simulate_proxy(
    schedule: Schedule | Sequence[Op],
    params: TransportParams,
    *,
    n_nodes: int,
    start_time: float = 0.0,
    ready_times: dict[int, float] | None = None,
) -> SimResult:
    """Run one PE's WR stream through the proxy+NIC pipeline.

    ``ready_times`` optionally delays the submission of a PUT (by tag) until
    e.g. the expert compute that produces it has finished — used for the
    combine phase of the end-to-end model.
    """
    ops = schedule.ops if isinstance(schedule, Schedule) else tuple(schedule)
    ready_times = ready_times or {}

    submit_cost = params.proxy_submit_us if params.proxy else params.gpu_submit_us
    now = start_time                      # proxy (or GPU submitter) clock
    wire_free = start_time                # shared egress port
    # NIC fence flags consult hardware completion registers scoped to
    # the *connection* ("all prior requests on the same connection", §4.2):
    # per-peer on Libfabric, per-QP on multi-QP IBRC where Perseus pins a
    # peer's WRs to qp = pe % num_qp (§5).  Proxy fences consult the
    # software completion counter (channel-wide).
    conn_last_hw_completion: dict[int, float] = {}
    inflight: list[tuple[float, int]] = []  # (sw_completion_time, conn)

    events: list[OpEvent] = []
    signal_visible: dict[int, float] = {}
    data_arrival: dict[int, float] = {}
    proxy_stall_total = 0.0
    nic_stall_total = 0.0
    wire_busy = 0.0
    n_fences = 0
    end_time = start_time

    def conn_of(dest_pe: int) -> int:
        # Ordering domain: the connection.  Multi-QP transports hash peers
        # onto QPs (Perseus peer-pinning, §5); single-channel transports
        # still keep one connection per remote peer.
        if params.num_qp > 1:
            return dest_pe % params.num_qp
        return dest_pe

    for op in ops:
        if op.kind is OpKind.PUT:
            ready = ready_times.get(op.tag, start_time)
            now = max(now, ready) + submit_cost
            conn = conn_of(op.dest_pe)
            wire_start = max(now, wire_free)
            w = params.wire_us(op.nbytes)
            wire_end = wire_start + w
            wire_free = wire_end
            wire_busy += w
            arrival = wire_end + params.alpha_us
            completion = wire_end + params.ack_us(n_nodes, op.nbytes)
            hw_completion = wire_end + params.hw_completion_us(op.nbytes)
            heapq.heappush(inflight, (completion, conn))
            conn_last_hw_completion[conn] = max(
                conn_last_hw_completion.get(conn, start_time), hw_completion
            )
            data_arrival[op.tag] = arrival
            end_time = max(end_time, arrival)
            events.append(OpEvent(op, now, wire_start, wire_end, arrival,
                                  completion, 0.0, 0.0))

        elif op.kind is OpKind.FENCE:
            # Proxy-side drain: block until every in-flight WR completed.
            n_fences += 1
            if params.inqp_ordering_free:
                # GPU-direct transports (IBGDA) order put->signal inside the
                # QP in hardware; the software fence is a no-op (§6.2).
                events.append(OpEvent(op, now, now, now, now, now, 0.0, 0.0))
                continue
            target = now
            while inflight:
                c, _ = heapq.heappop(inflight)
                target = max(target, c)
            stall = max(0.0, target - now) + params.drain_poll_us
            proxy_stall_total += stall
            now += stall
            events.append(OpEvent(op, now, now, now, now, now, stall, 0.0))

        elif op.kind in (OpKind.SIGNAL, OpKind.SIGNAL_FENCED):
            fenced = op.kind is OpKind.SIGNAL_FENCED
            now += params.signal_submit_us if params.proxy else submit_cost
            conn = conn_of(op.dest_pe)
            wire_start = max(now, wire_free)
            nic_stall = 0.0
            if fenced and not params.inqp_ordering_free:
                n_fences += 1
                # NIC defers the flagged WR until prior WRs on this
                # *connection* complete (hardware registers); the proxy does
                # NOT block (Fig. 2c).
                barrier = conn_last_hw_completion.get(
                    conn, start_time
                ) + params.nic_fence_us
                nic_stall = max(0.0, barrier - wire_start)
                wire_start = max(wire_start, barrier)
            elif fenced:
                n_fences += 1  # flag present but free (in-QP ordering)
            wire_end = wire_start + params.signal_wire_us
            wire_free = max(wire_free, wire_end)
            wire_busy += params.signal_wire_us
            visible = wire_end + params.alpha_us
            completion = wire_end + params.ack_us(n_nodes, 8)
            hw_completion = wire_end + params.hw_completion_us(8)
            heapq.heappush(inflight, (completion, conn))
            conn_last_hw_completion[conn] = max(
                conn_last_hw_completion.get(conn, start_time), hw_completion
            )
            signal_visible[op.tag] = visible
            nic_stall_total += nic_stall
            end_time = max(end_time, visible)
            events.append(OpEvent(op, now, wire_start, wire_end, visible,
                                  completion, 0.0, nic_stall))
        else:  # pragma: no cover
            raise ValueError(op.kind)

    # PUT-only schedules carry no signals: a consumer can only observe the
    # payload itself, so the tile becomes consumable at data arrival.  When
    # the schedule DOES carry signals, a PUT without a matching signal is
    # never announced to the receiver — leave it out of signal_visible
    # rather than silently aliasing it to the arrival time.
    if not _has_signals(ops):
        for tag, arr in data_arrival.items():
            signal_visible.setdefault(tag, arr)
    total = max(end_time, now) - start_time
    return SimResult(
        events=events,
        total_time=total,
        proxy_stall=proxy_stall_total,
        nic_stall=nic_stall_total,
        signal_visible=signal_visible,
        data_arrival=data_arrival,
        wire_busy=wire_busy,
        n_fences=n_fences,
    )


def _has_signals(ops: Iterable[Op]) -> bool:
    return any(
        o.kind in (OpKind.SIGNAL, OpKind.SIGNAL_FENCED) for o in ops
    )


def signaling_efficiency(
    *,
    n_transfers: int,
    nbytes: int,
    n_nodes: int,
    params: TransportParams,
    kind: ScheduleKind | str = ScheduleKind.COUPLED,
    group_size: int | None = None,
    pe_per_node: int = 4,
) -> float:
    """Fig. 5a metric: signaled throughput normalized to pipelined put-only."""
    n_dest = max(1, (n_nodes - 1) * pe_per_node)
    transfers = [
        Transfer(tag=i, dest_pe=1 + (i % n_dest), nbytes=nbytes,
                 dest_node=1 + (i % max(1, n_nodes - 1)))
        for i in range(n_transfers)
    ]
    base = simulate_proxy(
        build_schedule(transfers, ScheduleKind.PUT_ONLY),
        params, n_nodes=n_nodes,
    )
    test = simulate_proxy(
        build_schedule(transfers, kind, group_size=group_size),
        params, n_nodes=n_nodes,
    )
    return base.total_time / test.total_time


# --------------------------------------------------------------------------
# GPU compute model + end-to-end MoE layer
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GpuParams:
    name: str
    peak_tflops_bf16: float
    mfu: float                  # achievable fraction inside the megakernel

    def us_for_flops(self, flops: float, interference: float = 0.0) -> float:
        eff = self.peak_tflops_bf16 * 1e12 * self.mfu * (1.0 - interference)
        return flops / eff * 1e6


A100 = GpuParams("a100", 312.0, 0.55)
H100 = GpuParams("h100", 990.0, 0.50)


@dataclasses.dataclass(frozen=True)
class MoEModelSpec:
    """Paper Table 1 (+ Llama4-Scout used in Fig. 1)."""

    name: str
    hidden: int       # H
    intermediate: int  # I
    n_experts: int    # E
    top_k: int        # k
    n_moe_layers: int
    dtype_bytes: int = 2

    def expert_capacity(self, tokens: int) -> int:
        # EC = S * k / E (§6.1), per sending PE under balanced routing.
        return max(1, tokens * self.top_k // self.n_experts)

    def bytes_per_expert(self, tokens: int) -> int:
        return self.expert_capacity(tokens) * self.hidden * self.dtype_bytes

    def flops_per_token_expert(self) -> float:
        # Gated MLP: 3 GEMMs (gate/up/down) = 6 * H * I MAC-FLOPs per token
        # (the paper's "gated MLP factor x6" footnote).
        return 6.0 * self.hidden * self.intermediate

    def attn_flops_per_token(self) -> float:
        # Non-MoE per-layer work (QKV/O projections + gate): fixed per-layer
        # floor that bounds small-S speedups in the e2e model.
        return 8.0 * self.hidden * self.hidden + 2.0 * self.hidden * self.n_experts

    def compute_comm_ratio(self) -> float:
        """TFLOPs per GB moved (dispatch+combine), cf. paper footnote 2."""
        fl = self.top_k * self.flops_per_token_expert()
        vol = 2 * self.top_k * self.hidden * self.dtype_bytes
        return fl / vol / 1e3


QWEN3_30B = MoEModelSpec("qwen3-30b-a3b", 2048, 768, 128, 8, 48)
GPT_OSS_120B = MoEModelSpec("gpt-oss-120b", 2880, 2880, 128, 4, 36)
DEEPSEEK_V3 = MoEModelSpec("deepseek-v3", 7168, 2048, 256, 8, 58)
LLAMA4_SCOUT = MoEModelSpec("llama4-scout-17b", 5120, 8192, 16, 1, 24)

PAPER_MODELS = {
    m.name: m for m in (QWEN3_30B, GPT_OSS_120B, DEEPSEEK_V3, LLAMA4_SCOUT)
}


@dataclasses.dataclass
class LayerResult:
    latency_us: float
    dispatch: SimResult
    combine: SimResult
    compute_busy_us: float
    compute_span_us: float
    first_compute_us: float
    n_remote_transfers: int

    @property
    def utilization(self) -> float:
        return min(1.0, self.compute_busy_us / max(self.latency_us, 1e-9))


def _expert_token_counts(
    spec: MoEModelSpec, tokens: int, skew_zipf: float, n_pe: int
) -> list[int]:
    """Tokens routed to each expert by one sender (balanced or Zipf §6.4)."""
    E = spec.n_experts
    total = tokens * spec.top_k
    if skew_zipf <= 0:
        return [total // E] * E
    w = [1.0 / (r ** skew_zipf) for r in range(1, E + 1)]
    s = sum(w)
    counts = [max(0, int(round(total * x / s))) for x in w]
    return counts


def simulate_moe_layer(
    spec: MoEModelSpec,
    *,
    tokens_per_pe: int,
    n_nodes: int,
    pe_per_node: int,
    transport: TransportParams,
    gpu: GpuParams = A100,
    schedule: ScheduleKind | str = ScheduleKind.COUPLED,
    group_size: int | None = None,
    skew_zipf: float = 0.0,
    fused: bool = True,
) -> LayerResult:
    """One MoE layer (dispatch -> expert GEMMs -> combine) on one PE.

    Symmetric-traffic assumption: the tiles this PE *receives* have the same
    arrival-time distribution as the signal-visibility times of the tiles it
    *sends* (all PEs run the identical program on identically-sized shards).
    Expert compute is a single aggregate-GPU work queue.

    ``fused`` (default, the paper's megakernel and our ``backend="fused"``
    Pallas kernel): a tile's GEMMs may start the moment *its own* signal is
    visible, and its combine PUT is released as soon as its compute retires
    — tile-granular overlap, §2.3.

    ``fused=False`` models the *staged* path (``backend="megakernel"``:
    dispatch kernel, then a separate expert-FFN call, then a combine
    kernel): expert compute cannot start until **every** tile's signal is
    visible (the dispatch kernel's all-recv drain), and no combine PUT is
    released until **all** expert compute has finished — the two hidden
    barriers this repo's fused kernel removes.  The per-tile ready/release
    times of the two modes mirror the respective kernels, so modeled
    figures and the Pallas implementations agree on the mechanism.
    """
    kind = ScheduleKind(schedule)
    P = n_nodes * pe_per_node
    e_per_pe = spec.n_experts // max(1, P)
    if e_per_pe == 0:
        raise ValueError(
            f"{spec.name}: E={spec.n_experts} < P={P}; EP degree too large"
        )
    counts = _expert_token_counts(spec, tokens_per_pe, skew_zipf, P)

    # ---- dispatch: one tile per remote expert --------------------------
    my_pe, my_node = 0, 0
    transfers: list[Transfer] = []
    tag = 0
    local_tags: list[tuple[int, int]] = []  # (tag, tokens) staying on-node
    for pe in range(P):
        node = pe // pe_per_node
        for j in range(e_per_pe):
            e_idx = pe * e_per_pe + j
            tok = counts[e_idx]
            if tok == 0:
                continue
            nb = tok * spec.hidden * spec.dtype_bytes
            if node == my_node:
                local_tags.append((tag, tok))
            else:
                transfers.append(
                    Transfer(tag=tag, dest_pe=pe, nbytes=nb, dest_node=node)
                )
            tag += 1
    tok_of_tag = {}
    for t in transfers:
        tok_of_tag[t.tag] = t.nbytes // (spec.hidden * spec.dtype_bytes)
    for lt, tok in local_tags:
        tok_of_tag[lt] = tok

    dispatch = simulate_proxy(
        build_schedule(transfers, kind if kind is not ScheduleKind.PUT_ONLY
                       else ScheduleKind.PUT_ONLY, group_size=group_size),
        transport,
        n_nodes=n_nodes,
    )

    # ---- receive-side compute queue ------------------------------------
    # Mirrored arrivals: remote tiles become ready at the sender-side
    # signal-visible times; intra-node tiles ride NVLink.  The staged path
    # (fused=False) inserts the dispatch kernel's all-recv barrier: nothing
    # computes until the last signal is visible.
    interference = transport.sm_interference
    # Subscriber decode + scheduler enqueue per arriving tile (§2.3's
    # megakernel "OS"): small but bounds the speedup floor at tiny S.
    recv_tile_us = 1.0
    nv_per_tile = NVLINK.alpha_us + 2.0  # staging + NVLink store
    # Staged path: the dispatch kernel drains *every* recv before returning
    # — the remote signals AND the intra-node tiles' local DMAs.
    all_recv_barrier = max(
        [dispatch.signal_visible.get(t.tag, dispatch.total_time)
         for t in transfers]
        + ([nv_per_tile] if local_tags else []),
        default=0.0,
    )
    # (ready_us, duration_us, transfer index | -1 for intra-node tiles)
    jobs: list[tuple[float, float, int]] = []
    for idx, t in enumerate(transfers):
        if fused:
            ready = dispatch.signal_visible.get(t.tag, dispatch.total_time)
        else:
            ready = all_recv_barrier
        d = recv_tile_us + gpu.us_for_flops(
            tok_of_tag[t.tag] * spec.flops_per_token_expert(), interference
        )
        jobs.append((ready, d, idx))
    for lt, tok in local_tags:
        d = recv_tile_us + gpu.us_for_flops(
            tok * spec.flops_per_token_expert(), interference
        )
        jobs.append((nv_per_tile if fused else all_recv_barrier, d, -1))

    jobs.sort()
    clock = 0.0
    busy = 0.0
    # Keyed by *original transfer index* (jobs.sort() reorders the queue),
    # so the combine phase below releases each PUT at its own tile's retire
    # time, not an unrelated job's.
    finish_times: dict[int, float] = {}
    first_start = math.inf
    for r, d, idx in jobs:
        start = max(clock, r)
        first_start = min(first_start, start)
        clock = start + d
        busy += d
        if idx >= 0:
            finish_times[idx] = clock
    compute_span = clock - (first_start if jobs else 0.0)

    # ---- combine: return tiles as compute retires (fused) or after the
    # staged path's global compute barrier (separate combine kernel) ------
    combine_transfers: list[Transfer] = []
    ready_times: dict[int, float] = {}
    for idx, t in enumerate(transfers):
        ct = Transfer(tag=10_000 + t.tag, dest_pe=t.dest_pe,
                      nbytes=t.nbytes, dest_node=t.dest_node)
        combine_transfers.append(ct)
        ready_times[ct.tag] = finish_times[idx] if fused else clock
    combine = simulate_proxy(
        build_schedule(combine_transfers, kind if kind is not
                       ScheduleKind.PUT_ONLY else ScheduleKind.PUT_ONLY,
                       group_size=group_size),
        transport,
        n_nodes=n_nodes,
        start_time=max(dispatch.total_time, 0.0),
        ready_times=ready_times,
    )
    combine_done = (
        max(combine.signal_visible.values()) if combine.signal_visible
        else clock
    )
    # Final weighted accumulation of returned tiles (cheap, bandwidth-bound).
    local_done = clock
    # Per-layer non-MoE floor: attention projections, gate, norms, staging
    # and megakernel scheduling — serial with the dispatch of this layer.
    overhead = gpu.us_for_flops(
        tokens_per_pe * spec.attn_flops_per_token(), interference
    ) + 25.0
    latency = max(combine_done, local_done) + overhead
    return LayerResult(
        latency_us=latency,
        dispatch=dispatch,
        combine=combine,
        compute_busy_us=busy,
        compute_span_us=compute_span,
        first_compute_us=first_start if jobs else 0.0,
        n_remote_transfers=len(transfers),
    )


CROSS_LAYER_OVERLAP = 0.45
"""Fraction of per-layer communication overhead hidden by cross-layer
pipelining in a full forward pass.

A megakernel has no layer barriers: while the proxy drains layer L's fences,
processor CTAs run layer L/L+1 attention, norms and local-expert tiles, so
only part of the single-layer serialization (which Fig. 7/8 measure in
isolation and our `simulate_moe_layer` reproduces additively) lands on the
end-to-end critical path.  0.45 is calibrated jointly to Fig. 14 (19x
vanilla / 3.5x Perseus weak-scaling degradation at 16 nodes, S=1K) and
Fig. 1 (~10x at 8 nodes); see EXPERIMENTS.md for the validation deltas.
"""


def simulate_forward(
    spec: MoEModelSpec,
    *,
    tokens_per_pe: int,
    n_nodes: int,
    pe_per_node: int,
    transport: TransportParams,
    gpu: GpuParams = A100,
    schedule: ScheduleKind | str = ScheduleKind.COUPLED,
    group_size: int | None = None,
    skew_zipf: float = 0.0,
    fused: bool = True,
    cross_layer_overlap: float = CROSS_LAYER_OVERLAP,
) -> float:
    """Forward-pass latency (us) over all MoE layers.

    Per-layer latency = compute floor + the communication overhead that
    survives cross-layer overlap (see ``CROSS_LAYER_OVERLAP``).
    ``fused`` selects tile-granular overlap vs the staged barriers (see
    ``simulate_moe_layer``).
    """
    layer = simulate_moe_layer(
        spec,
        tokens_per_pe=tokens_per_pe,
        n_nodes=n_nodes,
        pe_per_node=pe_per_node,
        transport=transport,
        gpu=gpu,
        schedule=schedule,
        group_size=group_size,
        skew_zipf=skew_zipf,
        fused=fused,
    )
    overhead = gpu.us_for_flops(
        tokens_per_pe * spec.attn_flops_per_token(),
        transport.sm_interference,
    ) + 25.0
    compute_floor = layer.compute_busy_us + overhead
    comm_overhead = max(0.0, layer.latency_us - compute_floor)
    exposed = comm_overhead * (1.0 - cross_layer_overlap)
    return (compute_floor + exposed) * spec.n_moe_layers


# --------------------------------------------------------------------------
# ALLTOALL microbenchmark (Triton-distributed case study, Fig. 11/13)
# --------------------------------------------------------------------------


def alltoall_transfers(
    *, n_pe: int, pe_per_node: int, nbytes_per_peer: int
) -> list[Transfer]:
    out = []
    tag = 0
    for pe in range(1, n_pe):
        node = pe // pe_per_node
        if node == 0:
            continue  # NVLink
        out.append(Transfer(tag=tag, dest_pe=pe, nbytes=nbytes_per_peer,
                            dest_node=node))
        tag += 1
    return out


def simulate_alltoall(
    *,
    n_nodes: int,
    pe_per_node: int,
    nbytes_per_peer: int,
    transport: TransportParams,
    schedule: ScheduleKind | str,
    group_size: int | None = None,
) -> SimResult:
    transfers = alltoall_transfers(
        n_pe=n_nodes * pe_per_node,
        pe_per_node=pe_per_node,
        nbytes_per_peer=nbytes_per_peer,
    )
    return simulate_proxy(
        build_schedule(transfers, schedule, group_size=group_size),
        transport,
        n_nodes=n_nodes,
    )


def nccl_alltoall_latency(
    *,
    n_nodes: int,
    pe_per_node: int,
    nbytes_per_peer: int,
    transport: TransportParams,
    launch_overhead_us: float = 65.0,
    bw_efficiency: float = 0.85,
) -> float:
    """Host-initiated bulk collective model (Fig. 13 baseline).

    NCCL pays fixed kernel-launch + rendezvous overhead, then moves the
    inter-node volume at near-line-rate; completion is a global barrier.
    """
    remote_peers = (n_nodes - 1) * pe_per_node
    vol = remote_peers * nbytes_per_peer
    return (
        launch_overhead_us
        + vol / (transport.wire_GBps * bw_efficiency * 1e3)
        + transport.alpha_us * math.log2(max(2, n_nodes * pe_per_node))
    )


# --------------------------------------------------------------------------
# alpha-beta decomposition (Appendix A)
# --------------------------------------------------------------------------


def fit_alpha_beta(
    sizes_bytes: Sequence[float], latencies_us: Sequence[float]
) -> tuple[float, float, float]:
    """Least-squares fit T = alpha + beta*M. Returns (alpha_us, beta_us_per_B, R^2)."""
    n = len(sizes_bytes)
    if n < 2:
        raise ValueError("need >= 2 points")
    mx = sum(sizes_bytes) / n
    my = sum(latencies_us) / n
    sxx = sum((x - mx) ** 2 for x in sizes_bytes)
    sxy = sum((x - mx) * (y - my) for x, y in zip(sizes_bytes, latencies_us))
    beta = sxy / sxx if sxx else 0.0
    alpha = my - beta * mx
    ss_res = sum(
        (y - (alpha + beta * x)) ** 2
        for x, y in zip(sizes_bytes, latencies_us)
    )
    ss_tot = sum((y - my) ** 2 for y in latencies_us)
    r2 = 1.0 - ss_res / ss_tot if ss_tot else 1.0
    return alpha, beta, r2
