"""Top-k expert routing with capacity-based dispatch (GShard-style).

The routing layer is shared by every MoE backend (dense oracle, gathered
single-device, expert-parallel collective, Pallas megakernel).  It produces
*static-shape* dispatch/combine tensors so the whole MoE block stays
jit/pjit-compatible: tokens beyond an expert's capacity are dropped (the
paper's evaluation uses ``EC = S*k/E`` with balanced routing, §6.1, and
Zipf-skewed routing with capacity set to avoid drops, §6.4).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "RoutingInfo",
    "topk_routing",
    "expert_capacity",
    "zipf_gate_bias",
]


class RoutingInfo(NamedTuple):
    """Static-shape routing decision for one batch of tokens.

    Attributes:
      expert_idx:  (T, k) int32 — selected expert per (token, slot).
      weight:      (T, k) f32   — normalized gate weight per slot.
      position:    (T, k) int32 — position of the token inside its expert's
                                  capacity buffer; >= capacity means dropped.
      keep:        (T, k) bool  — slot survived the capacity cut.
      gate_probs:  (T, E) f32   — full softmax (for aux losses).
    """

    expert_idx: jax.Array
    weight: jax.Array
    position: jax.Array
    keep: jax.Array
    gate_probs: jax.Array


def expert_capacity(
    n_tokens: int, n_experts: int, top_k: int, capacity_factor: float = 1.25,
    multiple_of: int = 8,
) -> int:
    """EC = ceil(T*k/E * f), rounded up for TPU-friendly shapes."""
    raw = int(np.ceil(n_tokens * top_k / n_experts * capacity_factor))
    return max(multiple_of, int(np.ceil(raw / multiple_of)) * multiple_of)


def topk_routing(
    gate_logits: jax.Array,   # (T, E)
    top_k: int,
    capacity: int,
    *,
    renormalize: bool = True,
) -> RoutingInfo:
    """Select top-k experts per token and assign capacity positions.

    Position assignment is deterministic: tokens are served in index order
    (the standard GShard cumsum), so results are reproducible across
    backends — the per-kernel oracles rely on this.
    """
    T, E = gate_logits.shape
    probs = jax.nn.softmax(gate_logits.astype(jnp.float32), axis=-1)
    weight, expert_idx = jax.lax.top_k(probs, top_k)          # (T, k)
    if renormalize:
        weight = weight / jnp.clip(
            jnp.sum(weight, axis=-1, keepdims=True), 1e-9
        )

    # Flatten (token, slot) pairs in token-major order and compute each
    # pair's arrival index within its expert via a one-hot cumsum.
    flat_expert = expert_idx.reshape(-1)                       # (T*k,)
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)   # (T*k, E)
    # Position = number of earlier slots routed to the same expert.
    position_in_expert = jnp.cumsum(onehot, axis=0) - onehot   # exclusive
    position = jnp.take_along_axis(
        position_in_expert, flat_expert[:, None], axis=1
    )[:, 0].reshape(T, top_k)

    keep = position < capacity
    return RoutingInfo(
        expert_idx=expert_idx.astype(jnp.int32),
        weight=weight.astype(gate_logits.dtype),
        position=position.astype(jnp.int32),
        keep=keep,
        gate_probs=probs,
    )


def load_balance_loss(info: RoutingInfo) -> jax.Array:
    """Switch-style auxiliary loss: E * sum(frac_tokens * frac_probs)."""
    T, E = info.gate_probs.shape
    top1 = info.expert_idx[:, 0]
    frac_tokens = jnp.bincount(top1, length=E) / T
    frac_probs = jnp.mean(info.gate_probs, axis=0)
    return E * jnp.sum(frac_tokens * frac_probs)


def zipf_gate_bias(
    n_experts: int, skew: float, scale: float = 8.0
) -> np.ndarray:
    """Additive gate-logit bias inducing Zipf(skew) routing (paper §6.4).

    skew=0 is uniform; skew=1.5 concentrates ~82% of traffic on the top-10
    of 128 experts, matching the paper's most skewed setting.
    """
    if skew <= 0:
        return np.zeros((n_experts,), dtype=np.float32)
    ranks = np.arange(1, n_experts + 1, dtype=np.float64)
    probs = ranks ** (-skew)
    probs /= probs.sum()
    bias = np.log(probs) - np.log(probs).mean()
    return (scale * bias / max(1e-9, np.abs(bias).max())).astype(np.float32)
