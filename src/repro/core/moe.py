"""Expert-parallel Mixture-of-Experts block (the paper's workload).

Backends (selectable per call, identical numerics up to dispatch order):

  ``dense``       — oracle: every expert computes every token, outputs are
                    gate-weighted.  O(E) compute; used as the correctness
                    reference for everything else.
  ``gathered``    — single-device capacity dispatch (scatter -> expert
                    GEMMs -> combine).  This is what each EP rank runs
                    locally on its shard.
  ``collective``  — expert parallelism under ``shard_map``: capacity
                    dispatch + ``jax.lax.all_to_all`` (the bulk-synchronous
                    NCCL-style baseline in the paper, §2.2) + expert
                    compute + reverse all_to_all.
  ``megakernel``  — expert parallelism where dispatch/combine are the
                    Pallas remote-DMA kernel with a Perseus signaling
                    schedule (`repro.kernels.moe_dispatch`), but expert
                    compute is still a *separate* staged call: the dispatch
                    kernel drains every recv semaphore before the first
                    GEMM can start (a structural all-recv barrier).
  ``fused``       — the paper's true megakernel shape: dispatch remote-DMAs,
                    per-tile expert gated-MLP and combine remote-DMAs run in
                    ONE persistent Pallas kernel
                    (`repro.kernels.fused_megakernel`).  Each expert tile's
                    compute begins the moment *its* recv semaphore fires
                    (double-buffered HBM->VMEM loads), and each tile's
                    return DMA is released as soon as it retires — no
                    inter-stage barrier.  ``cfg.schedule`` still selects the
                    sender-side issue discipline (coupled / decoupled /
                    nic_ordered / perseus), so staged-vs-fused is a clean
                    A/B at fixed signaling semantics.

All backends share `topk_routing`, so token->expert assignment (including
capacity drops) is bit-identical and outputs can be compared directly.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core.routing import RoutingInfo, expert_capacity, topk_routing

__all__ = ["MoEParams", "MoEConfig", "init_moe", "moe_apply"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                   # per-expert intermediate size
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    activation: str = "silu"    # silu (gated) | gelu (gated)
    dtype: Any = jnp.bfloat16
    # EP settings (collective/megakernel backends):
    ep_axis: str = "model"
    # mesh axes the token dim is sharded over (EP axis must be last):
    token_axes: tuple[str, ...] = ("data", "model")
    # megakernel signaling schedule: coupled | decoupled | nic_ordered | perseus
    schedule: str = "perseus"


# Pytree: {'w_gate': (H,E), 'w1': (E,H,F), 'w3': (E,H,F), 'w2': (E,F,H)}.
MoEParams = dict


def init_moe(key: jax.Array, cfg: MoEConfig) -> MoEParams:
    kg, k1, k2, k3 = jax.random.split(key, 4)
    H, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    s_in = 1.0 / np.sqrt(H)
    s_ff = 1.0 / np.sqrt(F)
    return MoEParams(
        w_gate=(jax.random.normal(kg, (H, E)) * s_in).astype(jnp.float32),
        w1=(jax.random.normal(k1, (E, H, F)) * s_in).astype(cfg.dtype),
        w3=(jax.random.normal(k3, (E, H, F)) * s_in).astype(cfg.dtype),
        w2=(jax.random.normal(k2, (E, F, H)) * s_ff).astype(cfg.dtype),
    )


def _act(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(kind)


def _expert_ffn(x: jax.Array, w1, w3, w2, activation: str) -> jax.Array:
    """Gated MLP for one expert: (T,H) -> (T,H).  3 GEMMs (paper's x6 factor)."""
    h = _act(x @ w1, activation) * (x @ w3)
    return h @ w2


# ---------------------------------------------------------------------------
# dense oracle
# ---------------------------------------------------------------------------


def moe_dense(params: MoEParams, cfg: MoEConfig, x: jax.Array) -> jax.Array:
    """Reference: run all experts on all tokens; honors capacity drops."""
    T = x.shape[0]
    logits = x.astype(jnp.float32) @ params["w_gate"]
    cap = expert_capacity(T, cfg.n_experts, cfg.top_k, cfg.capacity_factor)
    info = topk_routing(logits, cfg.top_k, cap)
    outs = jax.vmap(
        lambda w1, w3, w2: _expert_ffn(
            x.astype(cfg.dtype), w1, w3, w2, cfg.activation
        )
    )(params["w1"], params["w3"], params["w2"])           # (E, T, H)
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for slot in range(cfg.top_k):
        e = info.expert_idx[:, slot]                      # (T,)
        w = info.weight[:, slot] * info.keep[:, slot]     # (T,)
        picked = jnp.take_along_axis(
            outs, e[None, :, None], axis=0
        )[0]                                              # (T, H)
        y = y + w[:, None].astype(jnp.float32) * picked.astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# gathered single-device dispatch (also the per-rank body for EP)
# ---------------------------------------------------------------------------


def _dispatch_to_buffers(
    x: jax.Array, info: RoutingInfo, n_experts: int, capacity: int
) -> jax.Array:
    """Scatter tokens into (E, C, H) capacity buffers."""
    T, H = x.shape
    k = info.expert_idx.shape[1]
    flat_idx = (
        info.expert_idx * capacity + jnp.minimum(info.position, capacity - 1)
    ).reshape(-1)                                          # (T*k,)
    keep = info.keep.reshape(-1)
    src = jnp.repeat(x, k, axis=0) * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((n_experts * capacity, H), dtype=x.dtype)
    # Dropped slots all collapse onto position capacity-1 with zero payload.
    safe_idx = jnp.where(keep, flat_idx, n_experts * capacity - 1)
    buf = buf.at[safe_idx].add(src, mode="drop")
    return buf.reshape(n_experts, capacity, H)


def _combine_from_buffers(
    expert_out: jax.Array,  # (E, C, H)
    info: RoutingInfo,
    capacity: int,
    out_dtype,
) -> jax.Array:
    T, k = info.expert_idx.shape
    flat_idx = (
        info.expert_idx * capacity + jnp.minimum(info.position, capacity - 1)
    ).reshape(-1)
    gathered = expert_out.reshape(-1, expert_out.shape[-1])[flat_idx]
    gathered = gathered.reshape(T, k, -1).astype(jnp.float32)
    w = (info.weight * info.keep).astype(jnp.float32)      # (T, k)
    return jnp.einsum("tkh,tk->th", gathered, w).astype(out_dtype)


def moe_gathered(
    params: MoEParams, cfg: MoEConfig, x: jax.Array
) -> jax.Array:
    """Single-device capacity dispatch -> batched expert GEMMs -> combine."""
    T = x.shape[0]
    logits = x.astype(jnp.float32) @ params["w_gate"]
    cap = expert_capacity(T, cfg.n_experts, cfg.top_k, cfg.capacity_factor)
    info = topk_routing(logits, cfg.top_k, cap)
    buf = _dispatch_to_buffers(x.astype(cfg.dtype), info, cfg.n_experts, cap)
    out = jax.vmap(
        lambda xb, w1, w3, w2: _expert_ffn(xb, w1, w3, w2, cfg.activation)
    )(buf, params["w1"], params["w3"], params["w2"])       # (E, C, H)
    return _combine_from_buffers(out, info, cap, x.dtype)


# ---------------------------------------------------------------------------
# expert-parallel backends (shard_map over the EP axis)
# ---------------------------------------------------------------------------


def _ep_body(
    params_local: MoEParams,
    x_local: jax.Array,         # (T_local, H) this rank's tokens
    cfg: MoEConfig,
    *,
    backend: str,
) -> jax.Array:
    """Per-rank EP body. params_local holds E/P experts; gate is replicated."""
    ep = cfg.ep_axis
    n_ranks = compat.axis_size(ep)
    E, k = cfg.n_experts, cfg.top_k
    e_local = E // n_ranks
    T_local = x_local.shape[0]

    logits = x_local.astype(jnp.float32) @ params_local["w_gate"]
    # Capacity per (source rank, expert): each source contributes up to C.
    cap = expert_capacity(T_local, E, k, cfg.capacity_factor)
    info = topk_routing(logits, k, cap)

    # (E, C, H) send buffers, grouped by destination rank:
    buf = _dispatch_to_buffers(x_local.astype(cfg.dtype), info, E, cap)
    buf = buf.reshape(n_ranks, e_local, cap, -1)           # (P, e, C, H)

    if backend == "fused":
        # One persistent kernel: dispatch DMAs + per-tile expert FFN +
        # combine DMAs, no inter-stage barrier (see fused_megakernel.py).
        from repro.kernels import fused_megakernel as fk

        back = fk.fused_moe_dispatch(
            buf,
            params_local["w1"], params_local["w3"], params_local["w2"],
            axis_name=ep, schedule=cfg.schedule,
            activation=cfg.activation,
        )                                                  # (P, e, C, H)
        back = back.reshape(E, cap, -1)
        return _combine_from_buffers(back, info, cap, x_local.dtype)

    if backend == "collective":
        # Bulk-synchronous ALLTOALL (the NCCL-style baseline).
        recv = jax.lax.all_to_all(
            buf, ep, split_axis=0, concat_axis=0, tiled=False
        )                                                  # (P, e, C, H)
    elif backend == "megakernel":
        from repro.kernels import moe_dispatch as mk

        recv = mk.remote_dispatch(
            buf, axis_name=ep, schedule=cfg.schedule
        )                                                  # (P, e, C, H)
    else:
        raise ValueError(backend)

    # Expert compute on everything we received: (e, P*C, H)
    xin = recv.transpose(1, 0, 2, 3).reshape(e_local, n_ranks * cap, -1)
    out = jax.vmap(
        lambda xb, w1, w3, w2: _expert_ffn(xb, w1, w3, w2, cfg.activation)
    )(xin, params_local["w1"], params_local["w3"], params_local["w2"])
    out = out.reshape(e_local, n_ranks, cap, -1).transpose(1, 0, 2, 3)

    if backend == "collective":
        back = jax.lax.all_to_all(
            out, ep, split_axis=0, concat_axis=0, tiled=False
        )
    else:
        from repro.kernels import moe_dispatch as mk

        back = mk.remote_dispatch(out, axis_name=ep, schedule=cfg.schedule)

    back = back.reshape(E, cap, -1)
    return _combine_from_buffers(back, info, cap, x_local.dtype)


def _ep_body_replicated(
    params_local: MoEParams,
    x_local: jax.Array,         # (T_local, H); replicated over the EP axis
    cfg: MoEConfig,
) -> jax.Array:
    """EP for tiny token counts (decode): every EP rank sees all tokens of
    its data shard, computes only *its* experts' contributions, and the
    results are summed over the EP axis — an all-reduce instead of two
    all-to-alls (the standard decode-time EP layout)."""
    ep = cfg.ep_axis
    n_ranks = compat.axis_size(ep)
    rank = jax.lax.axis_index(ep)
    E, k = cfg.n_experts, cfg.top_k
    e_local = E // n_ranks
    T = x_local.shape[0]

    logits = x_local.astype(jnp.float32) @ params_local["w_gate"]
    cap = expert_capacity(T, E, k, cfg.capacity_factor)
    info = topk_routing(logits, k, cap)
    buf = _dispatch_to_buffers(x_local.astype(cfg.dtype), info, E, cap)
    local = jax.lax.dynamic_slice_in_dim(buf, rank * e_local, e_local, axis=0)
    out = jax.vmap(
        lambda xb, w1, w3, w2: _expert_ffn(xb, w1, w3, w2, cfg.activation)
    )(local, params_local["w1"], params_local["w3"], params_local["w2"])
    full = jnp.zeros((E, cap, x_local.shape[-1]), dtype=out.dtype)
    full = jax.lax.dynamic_update_slice_in_dim(
        full, out, rank * e_local, axis=0
    )
    y = _combine_from_buffers(full, info, cap, jnp.float32)
    y = jax.lax.psum(y, ep)
    return y.astype(x_local.dtype)


def moe_apply(
    params: MoEParams,
    cfg: MoEConfig,
    x: jax.Array,
    *,
    backend: str = "gathered",
    mesh: Mesh | None = None,
    tokens_spec: P | None = None,
) -> jax.Array:
    """Apply the MoE block.

    ``collective``/``megakernel``/``fused``: ``x`` is (T, H) with T sharded
    over ``cfg.token_axes`` (EP dispatch runs over the last axis); ``fused``
    additionally folds the expert gated-MLP into the dispatch kernel.
    ``replicated``: T sharded over the non-EP token axes only; the EP axis
    contributes a psum (decode-time layout).  Expert weights are sharded
    over their leading (expert) axis; the gate is replicated.
    """
    if backend == "dense":
        return moe_dense(params, cfg, x)
    if backend == "gathered":
        return moe_gathered(params, cfg, x)
    if backend not in ("collective", "megakernel", "fused", "replicated"):
        raise ValueError(backend)

    ep = cfg.ep_axis
    if backend in ("megakernel", "fused") and mesh is not None:
        # The Pallas dispatch kernels address peers by flat LOGICAL device
        # id, which only coincides with the EP axis index when every other
        # mesh axis is trivial.  On a multi-axis mesh the DMAs would land
        # on devices in a *different* row of the non-EP axes — silently
        # corrupting data — so refuse instead (ROADMAP open item).
        extra = 1
        for a, s in mesh.shape.items():
            if a != ep:
                extra *= s
        if extra > 1:
            raise NotImplementedError(
                f"backend={backend!r} requires a mesh whose only "
                f"non-trivial axis is the EP axis {ep!r}; got "
                f"{dict(mesh.shape)}. Use backend='collective' or "
                "'replicated' on multi-axis meshes."
            )
    param_specs = MoEParams(
        w_gate=P(),
        w1=P(ep), w3=P(ep), w2=P(ep),
    )
    if backend == "replicated":
        dp_axes = tuple(a for a in cfg.token_axes if a != ep)
        tokens_spec = (
            tokens_spec if tokens_spec is not None
            else P(dp_axes if dp_axes else None)
        )
        body = functools.partial(_ep_body_replicated, cfg=cfg)
    else:
        tokens_spec = (
            tokens_spec if tokens_spec is not None else P(cfg.token_axes)
        )
        body = functools.partial(_ep_body, cfg=cfg, backend=backend)
    mapped = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(param_specs, tokens_spec),
        out_specs=tokens_spec,
    )
    return mapped(params, x)
