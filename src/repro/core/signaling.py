"""Signaling protocol schedules for megakernel expert dispatch.

This module is the *protocol layer* of the paper: it turns a logical
dispatch (a set of per-expert tile transfers to remote PEs) into the exact
stream of work requests the transport sees.  The four schedules mirror
Figure 2 / Figure 6 of the paper:

  ``coupled``      — vanilla PUT-WITH-SIGNAL: every transfer expands to
                     PUT -> proxy FENCE -> SIGNAL (one proxy drain per expert).
  ``decoupled``    — Perseus Algorithm 1: all PUTs submitted back-to-back,
                     then per destination *group* one proxy FENCE followed by
                     the group's SIGNALs (fence count = #groups).
  ``nic_ordered``  — coupled ordering but the fence is a NIC-side flag on the
                     SIGNAL work request (``FI_FENCE``/``IBV_SEND_FENCE``):
                     the proxy never blocks, the NIC defers the flagged WQE.
  ``perseus``      — both: all PUTs, then per group a single *flagged* SIGNAL
                     followed by the group's remaining plain SIGNALs.

The same schedule objects drive (a) the discrete-event transport simulator
(`transport_sim.py`) that reproduces the paper's performance results, and
(b) the Pallas TPU megakernel (`repro.kernels.moe_dispatch`), where a proxy
FENCE maps to a full send-semaphore drain and a NIC flag maps to the
hardware-coupled receive semaphore of the ICI DMA engine.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Iterable, Sequence

__all__ = [
    "OpKind",
    "Op",
    "Transfer",
    "Schedule",
    "ScheduleKind",
    "build_schedule",
    "group_by_destination",
    "fence_count",
    "optimal_group_size",
]


class OpKind(enum.Enum):
    PUT = "put"
    FENCE = "fence"           # proxy-side fence: drain all in-flight WRs
    SIGNAL = "signal"         # plain signal (small write)
    SIGNAL_FENCED = "signalF"  # signal carrying the NIC fence flag


class ScheduleKind(str, enum.Enum):
    COUPLED = "coupled"
    DECOUPLED = "decoupled"
    NIC_ORDERED = "nic_ordered"
    PERSEUS = "perseus"
    PUT_ONLY = "put_only"      # microbenchmark upper bound (Fig. 5a)


@dataclasses.dataclass(frozen=True)
class Transfer:
    """One logical tile transfer: tokens for one (remote) expert."""

    tag: int          # unique id; receivers wait on this tag's signal
    dest_pe: int      # destination processing element (global rank)
    nbytes: int       # payload size
    dest_node: int    # destination node (for intra/inter-node split)


@dataclasses.dataclass(frozen=True)
class Op:
    kind: OpKind
    dest_pe: int = -1
    nbytes: int = 0
    tag: int = -1
    dest_node: int = -1


@dataclasses.dataclass(frozen=True)
class Schedule:
    kind: ScheduleKind
    ops: tuple[Op, ...]
    group_size: int
    n_transfers: int

    @property
    def n_fences(self) -> int:
        return sum(
            1
            for o in self.ops
            if o.kind in (OpKind.FENCE, OpKind.SIGNAL_FENCED)
        )

    @property
    def n_proxy_fences(self) -> int:
        return sum(1 for o in self.ops if o.kind is OpKind.FENCE)


def group_by_destination(
    transfers: Sequence[Transfer], group_size: int | None
) -> list[list[Transfer]]:
    """Group transfers for fence amortization.

    ``group_size is None`` selects the paper's default *per-PE grouping*: one
    group per destination PE (§4.1, "Perseus defaults to per-PE grouping").
    Otherwise transfers are grouped destination-major in chunks of
    ``group_size`` (the tunable swept in Fig. 7).
    """
    by_dest: dict[int, list[Transfer]] = {}
    for t in transfers:
        by_dest.setdefault(t.dest_pe, []).append(t)
    ordered = [t for dest in sorted(by_dest) for t in by_dest[dest]]
    if group_size is None:
        return [by_dest[d] for d in sorted(by_dest)]
    group_size = max(1, int(group_size))
    return [
        list(ordered[i : i + group_size])
        for i in range(0, len(ordered), group_size)
    ]


def _put(t: Transfer) -> Op:
    return Op(OpKind.PUT, t.dest_pe, t.nbytes, t.tag, t.dest_node)


def _sig(t: Transfer, fenced: bool) -> Op:
    kind = OpKind.SIGNAL_FENCED if fenced else OpKind.SIGNAL
    return Op(kind, t.dest_pe, 0, t.tag, t.dest_node)


def build_schedule(
    transfers: Sequence[Transfer],
    kind: ScheduleKind | str,
    *,
    group_size: int | None = None,
) -> Schedule:
    """Expand logical transfers into the proxy-FIFO op stream.

    ``group_size`` only affects the decoupled/perseus schedules; ``None``
    means per-PE grouping (paper default).
    """
    kind = ScheduleKind(kind)
    ops: list[Op] = []
    transfers = list(transfers)

    if kind is ScheduleKind.PUT_ONLY:
        ops = [_put(t) for t in transfers]

    elif kind is ScheduleKind.COUPLED:
        # Vanilla NVSHMEM putmem_signal_nbi expansion (Fig. 2a / Fig. 6a).
        for t in transfers:
            ops.append(_put(t))
            ops.append(Op(OpKind.FENCE))
            ops.append(_sig(t, fenced=False))

    elif kind is ScheduleKind.NIC_ORDERED:
        # Fig. 2c: proxy never blocks; every signal carries the NIC flag.
        for t in transfers:
            ops.append(_put(t))
            ops.append(_sig(t, fenced=True))

    elif kind is ScheduleKind.DECOUPLED:
        # Fig. 2b / Algorithm 1: phase 1 = all PUTs, phase 2 = per group
        # (proxy FENCE, then the group's signals).
        groups = group_by_destination(transfers, group_size)
        for g in groups:
            ops.extend(_put(t) for t in g)
        for g in groups:
            ops.append(Op(OpKind.FENCE))
            ops.extend(_sig(t, fenced=False) for t in g)

    elif kind is ScheduleKind.PERSEUS:
        # Fig. 2d: all PUTs; only the first signal per group is flagged.
        # The NIC flag orders only within a peer's QP (§5 peer-hash
        # pinning), so when a tuned group spans multiple destinations the
        # flag must be carried by the first signal of each *destination*
        # within the group (per-PE default groups have exactly one).
        groups = group_by_destination(transfers, group_size)
        for g in groups:
            ops.extend(_put(t) for t in g)
        for g in groups:
            flagged_dests: set[int] = set()
            for t in g:
                first = t.dest_pe not in flagged_dests
                flagged_dests.add(t.dest_pe)
                ops.append(_sig(t, fenced=first))

    else:  # pragma: no cover
        raise ValueError(f"unknown schedule kind {kind}")

    gsz = group_size if group_size is not None else -1  # -1 == per-PE
    return Schedule(kind, tuple(ops), gsz, len(transfers))


def fence_count(
    n_transfers: int, kind: ScheduleKind | str, group_size: int | None,
    n_dest: int,
) -> int:
    """Closed-form fence count (proxy fences + flagged signals).

    For PERSEUS with an explicit ``group_size`` whose groups span several
    destinations, the true flag count depends on the destination layout
    (one flag per distinct destination per group) — this returns the
    per-PE-grouping lower bound; use ``Schedule.n_fences`` for exact counts.
    """
    kind = ScheduleKind(kind)
    if kind in (ScheduleKind.COUPLED, ScheduleKind.NIC_ORDERED):
        return n_transfers
    if kind is ScheduleKind.PERSEUS and group_size is not None:
        return max(n_dest, math.ceil(n_transfers / max(1, group_size)))
    if kind in (ScheduleKind.DECOUPLED, ScheduleKind.PERSEUS):
        if group_size is None:
            return n_dest
        return math.ceil(n_transfers / max(1, group_size))
    return 0


def optimal_group_size(
    n_transfers: int,
    drain_base_us: float,
    per_put_wait_us: float,
) -> int:
    """Beyond-paper extension: analytic group-size knee.

    Total fence cost for group size g ~ (N/g)*drain_base + N*per_put_wait*g/2
    (each fence waits on ~g/2 residual in-flight PUTs).  Minimizing over g
    gives g* = sqrt(2*N*drain_base / (N*per_put_wait)).  The paper sweeps
    this empirically (Fig. 7) and fixes per-PE grouping; we expose the
    analytic knee so the runtime can adapt to (S, nodes) without a sweep.
    """
    if per_put_wait_us <= 0:
        return n_transfers
    g = math.sqrt(2.0 * drain_base_us / per_put_wait_us)
    return max(1, min(n_transfers, int(round(g))))


def moe_dispatch_transfers(
    *,
    my_pe: int,
    n_pe: int,
    pe_per_node: int,
    n_experts: int,
    bytes_per_expert: int | Sequence[int],
) -> list[Transfer]:
    """Transfers one PE issues for one MoE dispatch phase.

    Each PE hosts E/P experts and sends one tile per *remote* expert
    (intra-node traffic rides NVLink/ICI-local and bypasses the proxy), i.e.
    (P - P_local) * (E/P) transfers (§3.2) — 96 in the paper's running
    Qwen3-30B example (4 nodes x 4 GPUs, 128 experts).
    """
    if n_experts % n_pe:
        raise ValueError(f"E={n_experts} not divisible by P={n_pe}")
    e_per_pe = n_experts // n_pe
    my_node = my_pe // pe_per_node
    transfers = []
    tag = 0
    for pe in range(n_pe):
        if pe == my_pe:
            continue
        node = pe // pe_per_node
        if node == my_node:
            continue  # NVLink path: no proxy involvement
        for _e in range(e_per_pe):
            nb = (
                bytes_per_expert
                if isinstance(bytes_per_expert, int)
                else int(bytes_per_expert[tag % len(bytes_per_expert)])
            )
            transfers.append(
                Transfer(tag=tag, dest_pe=pe, nbytes=nb, dest_node=node)
            )
            tag += 1
    return transfers
