"""repro.parallel subsystem."""
