"""GPipe-style pipeline parallelism over a mesh axis.

``pipeline_apply`` runs ``n_stages`` stage functions over ``n_micro``
microbatches using ``shard_map`` + ``ppermute``: every rank executes its
stage over a sliding window of microbatches, exchanging activations with
its neighbor each tick — n_stages + n_micro - 1 ticks total, bubble
fraction (n_stages-1)/(n_stages+n_micro-1).

The production configs default to DP over the ``pod`` axis (DESIGN.md §4);
this module exists so the launcher can flip ``--pp`` for models whose
per-pod footprint demands it, and is validated by a toy-model equivalence
test (pipeline output == sequential stack output).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro import compat
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["pipeline_apply"]


def pipeline_apply(
    stage_fn: Callable,        # (stage_params, x) -> x
    stage_params,              # pytree with leading n_stages axis (sharded)
    x: jax.Array,              # (n_micro, micro_batch, ...) microbatched input
    *,
    mesh: Mesh,
    axis: str = "pod",
) -> jax.Array:
    """Run the staged computation; returns outputs (n_micro, mb, ...)."""
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]

    def body(params_local, x_local):
        # params_local: this rank's stage params (leading axis stripped to 1)
        params_local = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        n_ticks = n_stages + n_micro - 1

        # state: activation buffer entering this stage each tick
        def tick(carry, t):
            inbuf, outputs = carry
            # stage 0 feeds itself from the microbatch stream
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            my_in = jnp.where(
                stage == 0,
                x_local[mb_idx],
                inbuf,
            )
            active = (t >= stage) & (t - stage < n_micro)
            y = stage_fn(params_local, my_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # pass activation to the next stage
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            # last stage records finished microbatches
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            record = (stage == n_stages - 1) & (t >= n_stages - 1)
            outputs = jnp.where(
                record,
                jax.lax.dynamic_update_index_in_dim(
                    outputs, y, out_idx, axis=0
                ),
                outputs,
            )
            return (nxt, outputs), None

        init_out = jnp.zeros((n_micro,) + x_local.shape[1:], x_local.dtype)
        (_, outputs), _ = jax.lax.scan(
            tick, (jnp.zeros_like(x_local[0]), init_out),
            jnp.arange(n_ticks),
        )
        # broadcast final outputs from the last stage to all ranks
        outputs = jax.lax.ppermute(
            outputs, axis,
            [((n_stages - 1 + i) % n_stages,
              (n_stages - 1 + i + 1) % n_stages)
             for i in range(n_stages)],
        ) if False else outputs
        total = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outputs,
                      jnp.zeros_like(outputs)),
            axis,
        )
        return total

    mapped = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
    )
    return mapped(stage_params, x)
