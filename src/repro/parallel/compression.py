"""Gradient compression for cross-pod (DCN) all-reduce.

``compressed_psum`` performs an int8 quantized all-reduce with per-tensor
scales; ``ErrorFeedback`` accumulates the quantization residual so the
compression is unbiased over steps (EF-SGD).  Intended for the ``pod`` axis
of the production mesh, where the inter-pod link is the beta-dominated term
(the intra-pod all-reduce stays full precision).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro import compat

__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum",
           "ef_compress_grads"]


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8 all-reduce: quantize -> psum int32 -> dequant with summed scale.

    All ranks share one scale via max-psum so the sum is exact in the
    quantized domain (no per-rank scale mismatch).
    """
    scale = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return total.astype(jnp.float32) * scale


def ef_compress_grads(grads, residual, axis_name: str):
    """Error-feedback compressed gradient sync over ``axis_name``.

    Returns (synced_grads, new_residual).  Call inside shard_map over the
    pod axis; pass residual zeros_like(grads) at step 0.
    """

    def one(g, r):
        g = g + r
        synced = compressed_psum(g, axis_name) / compat.axis_size(axis_name)
        # residual = what this rank contributed minus what quantization kept
        scale = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name) / 127.0 + 1e-12
        kept = jnp.clip(jnp.round(g / scale), -127, 127) * scale
        return synced, g - kept

    flat_g, tree = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    synced = tree.unflatten([o[0] for o in out])
    new_res = tree.unflatten([o[1] for o in out])
    return synced, new_res
