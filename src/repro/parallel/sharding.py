"""Partition-spec derivation for every parameter / activation in the zoo.

Axis convention (DESIGN.md §4):

  ``data``  (x ``pod``)  — batch / FSDP axis
  ``model``              — TP (heads, ffn, vocab) and EP (experts) axis

Rules are path-based over the parameter pytree, so they apply uniformly to
stacked period slots (leading ``n_periods`` dim is skipped automatically).
Explicit input shardings must divide exactly, so every rule is
divisibility-guarded with documented fallbacks:

  * KV-cache heads: kv-heads -> head_dim -> replicate (GQA kv counts like 4
    or 6 don't divide a 16-way model axis; the 128-wide head_dim does);
  * embeddings: vocab -> hidden -> replicate (mamba2's 50280 and whisper's
    51865 vocabs aren't multiples of 16);
  * batch: data axis when divisible, else sequence (SP) for long-context
    decode, else replicate.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec

__all__ = [
    "MeshAxes", "param_specs", "batch_specs", "cache_specs",
    "shardings_for", "count_bytes",
]


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    data: tuple[str, ...] = ("data",)   # ("pod","data") for multi-pod DP
    model: str = "model"
    data_size: int = 16                 # product over the data axes
    model_size: int = 16

    @property
    def dp(self):
        return self.data if len(self.data) > 1 else self.data[0]


def _pick(dim: int, size: int, axis):
    """Return ``axis`` if ``dim`` divides evenly over it, else None."""
    return axis if dim % size == 0 and dim >= size else None


def _spec_for_leaf(path: str, leaf, cfg: ArchConfig, ax: MeshAxes,
                   fsdp: bool) -> P:
    """Sharding rule table, keyed by parameter name within its block."""
    m, msz = ax.model, ax.model_size
    d, dsz = ax.dp, ax.data_size
    ndim = leaf.ndim
    shape = leaf.shape
    stacked = "slots" in path  # leading n_periods axis from the period scan
    off = 1 if stacked else 0
    lead: tuple = (None,) if stacked else ()

    def spec(*dims):
        out = lead + dims
        out = out + (None,) * (ndim - len(out))
        return P(*out[:ndim])

    def dim(i):
        return shape[off + i] if off + i < len(shape) else 1

    parts = path.split("/")
    name = parts[-1]
    parent = parts[-2] if len(parts) > 1 else ""
    # quantized optimizer moments: shard int8 payload like its parameter;
    # per-row scales are small and stay replicated.
    if name == "q":
        name, parent = parent, (parts[-3] if len(parts) > 2 else "")
    elif name == "s" and parent not in ("mixer", "ffn"):
        return spec()

    # --- embeddings: vocab over model, fallback hidden ------------------
    if name == "tok":
        if shape[0] % msz == 0:
            return P(m, None)
        if shape[1] % msz == 0:
            return P(None, m)
        return P(None, None)
    if name == "head" and parent == "embed":
        return P(None, _pick(shape[1], msz, m))

    # --- MoE experts: EP over model; optional FSDP over data ------------
    if parent == "ffn" and name in ("w1", "w3", "w2") and ndim - off == 3:
        e_ax = _pick(dim(0), msz, m)
        f_ax = _pick(dim(1), dsz, d) if fsdp else None
        return spec(e_ax, f_ax, None)
    if name == "w_gate":
        return spec(None, None)

    # --- projections: output-dim TP in, input-dim TP out ----------------
    if name in ("wq", "wk", "wv", "wi", "wr", "in_x", "in_z", "w1", "w3"):
        return spec(
            _pick(dim(0), dsz, d) if fsdp else None,
            _pick(dim(1), msz, m),
        )
    if name in ("wo", "out", "w2"):
        return spec(
            _pick(dim(0), msz, m),
            _pick(dim(1), dsz, d) if fsdp else None,
        )

    # --- small vectors / norms / conv: replicated ------------------------
    return spec()


def param_specs(params, cfg: ArchConfig, ax: MeshAxes | None = None,
                *, fsdp: bool = False):
    """PartitionSpec pytree matching ``params``."""
    ax = ax or MeshAxes()

    def walk(path_parts, leaf):
        path = "/".join(str(p) for p in path_parts)
        return _spec_for_leaf(path, leaf, cfg, ax, fsdp)

    return jax.tree_util.tree_map_with_path(
        lambda kp, x: walk([_key_str(k) for k in kp], x), params
    )


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def batch_specs(cfg: ArchConfig, shape: ShapeSpec, ax: MeshAxes | None = None):
    """Specs for the input batch dict (tokens/labels/frames/img_embeds)."""
    ax = ax or MeshAxes()
    d, dsz = ax.dp, ax.data_size
    b_ax = d if shape.global_batch % dsz == 0 else None
    specs: dict[str, P] = {}
    if shape.kind in ("train", "prefill"):
        specs["tokens"] = P(b_ax, None)
        specs["labels"] = P(b_ax, None)
        if cfg.family == "audio":
            specs["frames"] = P(b_ax, None, None)
        if cfg.family == "vlm":
            specs["img_embeds"] = P(b_ax, None, None)
    else:  # decode
        specs["tokens"] = P(b_ax, None)
    return specs


def cache_specs(cfg: ArchConfig, shape: ShapeSpec, caches,
                ax: MeshAxes | None = None):
    """Decode-cache specs.

    Batch over data when divisible; otherwise (long-context, batch=1) the
    *sequence* axis is sharded over data (SP) — GSPMD inserts the
    softmax-stable reductions.  Head-like axes go over model with the
    kv-heads -> head_dim -> replicate fallback.
    """
    ax = ax or MeshAxes()
    d, dsz = ax.dp, ax.data_size
    m, msz = ax.model, ax.model_size
    batch_ax = d if shape.global_batch % dsz == 0 else None

    def leaf_spec(path_parts, leaf):
        path = "/".join(_key_str(k) for k in path_parts)
        stacked = "slots" in path
        off = 1 if stacked else 0
        lead: tuple = (None,) if stacked else ()
        name = path.split("/")[-1]
        nd = leaf.ndim
        shape_ = leaf.shape

        def dim(i):
            return shape_[off + i] if off + i < len(shape_) else 1

        def spec(*dims):
            out = lead + dims
            out = out + (None,) * (nd - len(out))
            return P(*out[:nd])

        if name in ("k", "v"):
            # (B, S, nkv, hd).  Preferred: kv heads over model.  When the
            # head count doesn't divide, shard the *sequence* over model
            # (flash-decode layout: per-shard partial attention + psum of
            # the softmax stats) — sharding head_dim instead provokes
            # GSPMD's involuntary full rematerialization (replicates the
            # whole cache per layer).
            h_ax = _pick(dim(2), msz, m)
            if h_ax:
                s_ax = None if batch_ax else _pick(dim(1), dsz, d)
                return spec(batch_ax, s_ax, h_ax, None)
            s_ax = _pick(dim(1), msz, m)
            return spec(batch_ax, s_ax, None, None)
        if name == "s":       # SSD state (B, nh, N, dh)
            h_ax = _pick(dim(1), msz, m)
            n_ax = None if h_ax else _pick(dim(2), msz, m)
            return spec(batch_ax, h_ax, n_ax, None)
        if name == "h":       # RG-LRU state (B, H)
            return spec(batch_ax, _pick(dim(1), msz, m))
        if name == "conv":    # (B, K-1, C)
            return spec(batch_ax, None, _pick(dim(2), msz, m))
        return spec()

    return jax.tree_util.tree_map_with_path(
        lambda kp, x: leaf_spec(kp, x), caches
    )


def shardings_for(mesh: Mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def count_bytes(tree) -> int:
    return sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x in jax.tree.leaves(tree)
    )
