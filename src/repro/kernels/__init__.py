"""Pallas TPU kernels (interpret-mode validated on CPU, Mosaic on TPU).

Each kernel module pairs with a pure-jnp oracle in ``ref.py``; ``ops.py``
holds the jit'd public wrappers."""
