"""Pure-jnp oracles for every Pallas kernel in this package.

Each function computes the same math as its kernel with straightforward
jax.numpy — no tiling, no DMA, no online softmax — and is the ground truth
for the per-kernel ``assert_allclose`` sweeps in ``tests/test_kernels.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "dispatch_ref",
    "expert_ffn_ref",
    "attention_ref",
    "ssd_scan_ref",
]


def dispatch_ref(global_buf: jax.Array, n_ranks: int) -> jax.Array:
    """Oracle for ``moe_dispatch.remote_dispatch`` at the *global* view.

    ``global_buf``: (P*P, e, C, H) — rank r's send buffer occupies rows
    [r*P, (r+1)*P) with row (r*P + d) destined for rank d.  The output in
    rank d's shard row s must be what rank s sent to d (ALLTOALL semantics,
    i.e. a transpose of the (src, dst) block matrix).
    """
    P = n_ranks
    rest = global_buf.shape[1:]
    g = global_buf.reshape((P, P) + rest)      # [src, dst, ...]
    return jnp.swapaxes(g, 0, 1).reshape((P * P,) + rest)


def expert_ffn_ref(
    x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array,
    *, activation: str = "silu",
) -> jax.Array:
    """Oracle for ``expert_gemm.expert_ffn``: per-expert gated MLP in f32."""
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[activation]

    def one(xe, w1e, w3e, w2e):
        xf = xe.astype(jnp.float32)
        h = act(xf @ w1e.astype(jnp.float32)) * (xf @ w3e.astype(jnp.float32))
        return h @ w2e.astype(jnp.float32)

    return jax.vmap(one)(x, w1, w3, w2).astype(x.dtype)


def attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool = True, scale: float | None = None,
) -> jax.Array:
    """Oracle for ``flash_attention``: materialized-softmax GQA attention."""
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Tq, Tk), dtype=bool), k=Tk - Tq)
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_scan_ref(
    x: jax.Array,     # (B, L, H, Dh)
    dt: jax.Array,    # (B, L, H)
    a: jax.Array,     # (H,)
    bmat: jax.Array,  # (B, L, H, N)
    cmat: jax.Array,  # (B, L, H, N)
) -> jax.Array:
    """Oracle for ``ssd_scan``: step-by-step recurrence via lax.scan."""
    B, L, H, Dh = x.shape
    N = bmat.shape[-1]

    def step(s, inp):
        xt, dtt, bt, ct = inp           # (H,Dh),(H,),(H,N),(H,N)
        decay = jnp.exp(dtt * a)        # (H,)
        s = s * decay[:, None, None] + (
            dtt[:, None, None] * bt[:, :, None] * xt[:, None, :]
        )                               # (H, N, Dh)
        y = jnp.einsum("hn,hnd->hd", ct, s)
        return s, y

    def one_batch(xb, dtb, bb, cb):
        s0 = jnp.zeros((H, N, Dh), dtype=jnp.float32)
        _, ys = jax.lax.scan(
            step, s0,
            (xb.astype(jnp.float32), dtb.astype(jnp.float32),
             bb.astype(jnp.float32), cb.astype(jnp.float32)),
        )
        return ys                        # (L, H, Dh)

    return jax.vmap(one_batch)(x, dt, bmat, cmat).astype(x.dtype)
