"""Jit'd public wrappers around the Pallas kernels.

These are what the model zoo and the MoE block call; each wrapper owns the
jit boundary, default block sizes, and the CPU-interpret/TPU-compiled
switch, so call sites never touch pallas_call directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import expert_gemm as _expert_gemm
from repro.kernels import flash_attention as _flash
from repro.kernels import fused_megakernel as _fused
from repro.kernels import moe_dispatch as _dispatch
from repro.kernels import ssd_scan as _ssd

__all__ = [
    "remote_dispatch",
    "fused_moe_dispatch",
    "expert_ffn",
    "flash_attention",
    "ssd_scan",
]

# Re-export: remote_dispatch / fused_moe_dispatch must run *inside*
# shard_map, so they cannot be independently jit'd here; the MoE block owns
# its jit boundary.
remote_dispatch = _dispatch.remote_dispatch
fused_moe_dispatch = _fused.fused_moe_dispatch


@functools.partial(
    jax.jit, static_argnames=("activation", "block_t", "block_f")
)
def expert_ffn(
    x, w1, w3, w2, *, activation: str = "silu",
    block_t: int = 128, block_f: int = 128,
):
    """(E,T,H),(E,H,F),(E,H,F),(E,F,H) -> (E,T,H) fused gated MLP."""
    return _expert_gemm.expert_ffn(
        x, w1, w3, w2, activation=activation,
        block_t=block_t, block_f=block_f,
    )


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k")
)
def flash_attention(
    q, k, v, *, causal: bool = True, block_q: int = 128, block_k: int = 128,
):
    """(B,Hq,T,D) x (B,Hkv,T,D)^2 -> (B,Hq,T,D) blockwise attention."""
    return _flash.flash_attention(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
    )


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, a, bmat, cmat, *, chunk: int = 128):
    """Mamba-2 SSD chunked scan; see ssd_scan.py for shapes."""
    return _ssd.ssd_scan(x, dt, a, bmat, cmat, chunk=chunk)
