"""Fused MoE megakernel: dispatch -> expert GEMMs -> combine in ONE kernel.

The staged path (``moe_dispatch.remote_dispatch`` followed by a separate
expert-FFN call) still contains the paper's *hidden serialization* in
structural form: the dispatch kernel waits on **all** recv semaphores before
returning, so the first expert GEMM cannot start until the last tile has
landed — a bulk-synchronous barrier in megakernel clothing (§2.2).  This
kernel removes it.  One ``pallas_call`` per rank:

  1. **Issue** — every dispatch remote-DMA is started up front under the
     selected sender-side discipline (same schedules as ``moe_dispatch``):

       ``coupled``     per-tile ``wait_send`` drain after each start (the
                       proxy-FENCE-per-PUT analogue, Fig. 2a);
       ``decoupled``   per-destination-group bursts, one batched drain per
                       group (Perseus Algorithm 1);
       ``perseus`` /   everything in flight at once; the *terminal* drain is
       ``nic_ordered`` deferred to kernel exit, i.e. fully overlapped with
                       expert compute (Fig. 2d + this repo's fusion).

  2. **Compute** — tiles are processed expert-major; each tile's
     ``wait_recv`` fires on *its own* (source, expert) semaphore, so a
     tile's gated-MLP starts the moment its payload lands.  HBM->VMEM tile
     loads are double-buffered, with tile *i+1*'s recv-wait + prefetch
     placed *after* tile *i*'s GEMMs so ready compute is never gated on a
     later tile's arrival (the prefetch instead overlaps tile *i*'s
     result-store drain and combine release).  The compute body is
     ``expert_gemm.tile_ffn`` — the same code the standalone grid kernel
     accumulates with.

  3. **Combine** — the moment a tile's FFN output is back in HBM, its
     return remote-DMA is released toward the source rank (per-tile
     ``wait_send`` under ``coupled``; deferred drains otherwise).  No
     global barrier exists anywhere between a tile landing and its result
     departing; the only full rendezvous is the kernel-exit wait on the
     combine recv semaphores, which is the data dependency itself.

Memory plan: payload refs live in ``pl.ANY`` (HBM); ``recv``/``out``
staging buffers are extra kernel *outputs* in ANY space (discarded by the
wrapper — scratch cannot live in HBM).  VMEM holds one expert's weights
plus double-buffered (C, H) activation/output tiles.  Weights are reloaded
once per local expert (expert-major order); a production multi-layer
persistent kernel would double-buffer those too (see ROADMAP open items).

Correctness is validated on CPU in interpret mode (cross-device DMAs fully
interpreted); on TPU the same code lowers to Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.kernels.expert_gemm import tile_ffn
from repro.kernels.moe_dispatch import SCHEDULES

__all__ = ["fused_moe_dispatch", "SCHEDULES"]


def _fused_kernel(
    # inputs (ANY/HBM)
    buf_ref,          # (P, e, C, H) send tiles; buf[dst, j] -> rank dst
    w1_ref,           # (e, H, F) local expert gate proj
    w3_ref,           # (e, H, F) local expert up proj
    w2_ref,           # (e, F, H) local expert down proj
    # outputs (ANY/HBM)
    y_ref,            # (P, e, C, H) combined returns; y[src, j] = results
    #                   computed by expert-host `src` for MY tokens
    recv_ref,         # (P, e, C, H) staging: tiles received for MY experts
    out_ref,          # (P, e, C, H) staging: FFN outputs awaiting combine
    # DMA semaphores
    disp_send,        # (P, e)
    disp_recv,        # (P, e)  slot [0, j] doubles as the local-copy sem
    comb_send,        # (P, e)
    comb_recv,        # (P, e)  slot [0, j] doubles as the local-copy sem
    x_sem,            # (2,)  HBM->VMEM tile loads
    o_sem,            # (2,)  VMEM->HBM result stores
    w_sem,            # (3,)  weight loads
    # VMEM scratch
    x_vmem,           # (2, C, H)
    o_vmem,           # (2, C, H)
    w1_vmem,          # (H, F)
    w3_vmem,          # (H, F)
    w2_vmem,          # (F, H)
    *,
    num_ranks: int,
    e_local: int,
    axis_name: str,
    schedule: str,
    activation: str,
):
    me = lax.axis_index(axis_name)

    def disp_copy(offset, j):
        """Dispatch tile j to rank (me+offset); by symmetry the matching
        incoming tile (from rank me-offset) lands on sem slot [offset, j]."""
        dst = lax.rem(me + offset, num_ranks)
        return pltpu.make_async_remote_copy(
            src_ref=buf_ref.at[dst, j],
            dst_ref=recv_ref.at[me, j],
            send_sem=disp_send.at[offset, j],
            recv_sem=disp_recv.at[offset, j],
            device_id=dst,
            device_id_type=pltpu.DeviceIdType.LOGICAL,
        )

    def comb_copy(offset, j):
        """Return the tile computed for rank (me-offset) to its y[me, j];
        incoming returns (from expert host me+offset) land on [offset, j]."""
        src = lax.rem(me + num_ranks - offset, num_ranks)
        return pltpu.make_async_remote_copy(
            src_ref=out_ref.at[src, j],
            dst_ref=y_ref.at[me, j],
            send_sem=comb_send.at[offset, j],
            recv_sem=comb_recv.at[offset, j],
            device_id=src,
            device_id_type=pltpu.DeviceIdType.LOGICAL,
        )

    def local_disp(j):
        return pltpu.make_async_copy(
            buf_ref.at[me, j], recv_ref.at[me, j], disp_recv.at[0, j]
        )

    def local_comb(j):
        return pltpu.make_async_copy(
            out_ref.at[me, j], y_ref.at[me, j], comb_recv.at[0, j]
        )

    # ---- phase 1: issue all dispatch DMAs (sender-side discipline) ------
    for j in range(e_local):
        local_disp(j).start()
    deferred_disp_drains = []
    if schedule == "coupled":
        for offset in range(1, num_ranks):
            for j in range(e_local):
                c = disp_copy(offset, j)
                c.start()
                c.wait_send()            # proxy-FENCE analogue: per-tile drain
    elif schedule == "decoupled":
        for offset in range(1, num_ranks):
            group = [disp_copy(offset, j) for j in range(e_local)]
            for c in group:
                c.start()
            for c in group:
                c.wait_send()            # one batched drain per destination
    elif schedule in ("perseus", "nic_ordered"):
        for offset in range(1, num_ranks):
            for j in range(e_local):
                c = disp_copy(offset, j)
                c.start()
                deferred_disp_drains.append(c)   # terminal drain at exit:
                #                                  fully overlapped w/ compute
    else:  # pragma: no cover
        raise ValueError(f"unknown schedule {schedule!r}")

    # ---- phase 2+3: per-tile recv-wait -> FFN -> combine release --------
    # Expert-major order: one weight load per local expert; within an
    # expert the (C, H) tiles from the P sources are double-buffered.
    def tile_ready(offset, j):
        if offset == 0:
            local_disp(j).wait()         # self block rode the local DMA
        else:
            disp_copy(offset, j).wait_recv()

    def start_load(offset, j, slot):
        src = lax.rem(me + num_ranks - offset, num_ranks)
        return pltpu.make_async_copy(
            recv_ref.at[src, j], x_vmem.at[slot], x_sem.at[slot]
        )

    deferred_comb_drains = []
    for j in range(e_local):
        w_loads = [
            pltpu.make_async_copy(w1_ref.at[j], w1_vmem, w_sem.at[0]),
            pltpu.make_async_copy(w3_ref.at[j], w3_vmem, w_sem.at[1]),
            pltpu.make_async_copy(w2_ref.at[j], w2_vmem, w_sem.at[2]),
        ]
        for c in w_loads:
            c.start()
        tile_ready(0, j)
        load = start_load(0, j, 0)
        load.start()
        loads = {0: load}
        for c in w_loads:
            c.wait()
        for offset in range(num_ranks):
            slot = offset % 2
            loads.pop(offset).wait()
            y = tile_ffn(
                x_vmem[slot], w1_vmem[...], w3_vmem[...], w2_vmem[...],
                activation=activation,
            )
            o_vmem[slot] = y.astype(o_vmem.dtype)
            src = lax.rem(me + num_ranks - offset, num_ranks)
            store = pltpu.make_async_copy(
                o_vmem.at[slot], out_ref.at[src, j], o_sem.at[slot]
            )
            store.start()
            if offset + 1 < num_ranks:
                # Prefetch tile i+1 into the other VMEM slot.  Its recv-wait
                # sits AFTER tile i's GEMMs on purpose: blocking before the
                # compute would gate ready work on a later tile's arrival —
                # exactly the head-of-line serialization this kernel exists
                # to remove.  The load itself overlaps tile i's result-store
                # drain and combine release.
                tile_ready(offset + 1, j)
                nxt = start_load(offset + 1, j, (offset + 1) % 2)
                nxt.start()
                loads[offset + 1] = nxt
            store.wait()                 # remote copy must read a full tile
            if offset == 0:
                local_comb(j).start()    # self result: local DMA into y
            else:
                c = comb_copy(offset, j)
                c.start()                # tile retired -> release its return
                if schedule == "coupled":
                    c.wait_send()
                else:
                    deferred_comb_drains.append(c)

    # ---- exit: terminal drains + the combine data dependency ------------
    for c in deferred_disp_drains:
        c.wait_send()
    for c in deferred_comb_drains:
        c.wait_send()
    for j in range(e_local):
        local_comb(j).wait()
        for offset in range(1, num_ranks):
            comb_copy(offset, j).wait_recv()


def fused_moe_dispatch(
    buf: jax.Array,   # (P, e_local, C, H)
    w1: jax.Array,    # (e_local, H, F)
    w3: jax.Array,    # (e_local, H, F)
    w2: jax.Array,    # (e_local, F, H)
    *,
    axis_name: str,
    schedule: str = "perseus",
    activation: str = "silu",
    interpret: bool | None = None,
) -> jax.Array:
    """Dispatch + expert gated-MLP + combine as one persistent Pallas kernel.

    Must be called inside ``shard_map`` over ``axis_name``.  ``buf[dst]``
    holds the expert tiles destined for rank ``dst`` (same layout as
    ``remote_dispatch``); ``w1/w3/w2`` are this rank's local expert weights.

    Returns ``(P, e_local, C, H)``: ``y[src, j]`` is the FFN output that
    expert host ``src`` computed for the tokens this rank sent it — i.e.
    exactly ``remote_dispatch(expert_ffn(remote_dispatch(buf)))`` of the
    staged path, with no inter-stage barrier.
    """
    if schedule not in SCHEDULES:
        raise ValueError(f"schedule must be one of {SCHEDULES}, got {schedule}")
    num_ranks = compat.axis_size(axis_name)
    if buf.shape[0] != num_ranks:
        raise ValueError(
            f"buf leading dim {buf.shape[0]} != axis size {num_ranks}"
        )
    e_local, cap, hidden = buf.shape[1], buf.shape[2], buf.shape[3]
    if w1.shape[0] != e_local or w1.shape[1] != hidden:
        raise ValueError(f"w1 {w1.shape} mismatches buf {buf.shape}")
    d_ff = w1.shape[-1]
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"

    kernel = functools.partial(
        _fused_kernel,
        num_ranks=num_ranks,
        e_local=e_local,
        axis_name=axis_name,
        schedule=schedule,
        activation=activation,
    )
    y, _recv, _out = pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct(buf.shape, buf.dtype),   # y
            jax.ShapeDtypeStruct(buf.shape, buf.dtype),   # recv staging
            jax.ShapeDtypeStruct(buf.shape, buf.dtype),   # out staging
        ),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 4,
        out_specs=(pl.BlockSpec(memory_space=pl.ANY),) * 3,
        scratch_shapes=[
            pltpu.SemaphoreType.DMA((num_ranks, e_local)),   # disp_send
            pltpu.SemaphoreType.DMA((num_ranks, e_local)),   # disp_recv
            pltpu.SemaphoreType.DMA((num_ranks, e_local)),   # comb_send
            pltpu.SemaphoreType.DMA((num_ranks, e_local)),   # comb_recv
            pltpu.SemaphoreType.DMA((2,)),                   # x_sem
            pltpu.SemaphoreType.DMA((2,)),                   # o_sem
            pltpu.SemaphoreType.DMA((3,)),                   # w_sem
            pltpu.VMEM((2, cap, hidden), buf.dtype),
            pltpu.VMEM((2, cap, hidden), buf.dtype),
            pltpu.VMEM((hidden, d_ff), w1.dtype),
            pltpu.VMEM((hidden, d_ff), w3.dtype),
            pltpu.VMEM((d_ff, hidden), w2.dtype),
        ],
        interpret=compat.pallas_interpret(interpret),
        compiler_params=compat.tpu_compiler_params(
            has_side_effects=True,
            collective_id=8,
        ),
    )(buf, w1, w3, w2)
    return y
