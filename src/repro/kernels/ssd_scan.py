"""Mamba-2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

Implements the selective-state recurrence used by the ``mamba2-780m`` arch::

    S_t = exp(dt_t * A_h) * S_{t-1} + dt_t * (B_t (x) x_t)      # (N, Dh)
    y_t = C_t @ S_t + D_h * x_t

via the SSD chunk decomposition (arXiv:2405.21060): within a chunk of
length ``Lc`` the contribution is a masked attention-like product
(``(C B^T) * decay``), and chunks exchange a single (N, Dh) state carried
through VMEM scratch across sequential grid steps.

Tiling: grid ``(B, H, L/Lc)`` with the chunk axis innermost/sequential.
VMEM per step: x (Lc, Dh), B/C (Lc, N), dt (Lc, 1), state (N, Dh) f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

__all__ = ["ssd_scan"]


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, state_ref, *,
            n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (Lc, Dh)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (Lc, 1)
    a = a_ref[0, 0, 0]                           # scalar A_h (<0)
    bmat = b_ref[0, 0].astype(jnp.float32)       # (Lc, N)
    cmat = c_ref[0, 0].astype(jnp.float32)       # (Lc, N)

    Lc = x.shape[0]
    # log-decay per step and cumulative sums (inclusive).
    la = dt * a                                  # (Lc, 1)
    cum = jnp.cumsum(la, axis=0)                 # sum_{u<=t} la_u

    # ---- inter-chunk: y_inter[t] = (C_t @ S_prev) * exp(cum_t)
    s_prev = state_ref[...]                      # (N, Dh)
    y_inter = jnp.dot(
        cmat, s_prev, preferred_element_type=jnp.float32
    ) * jnp.exp(cum)                             # (Lc, Dh)

    # ---- intra-chunk: M[t,s] = (C_t . B_s) * exp(cum_t - cum_s) * dt_s,
    #       s <= t  (decay over (s, t] == cum_t - cum_s).
    scores = jnp.dot(cmat, bmat.T, preferred_element_type=jnp.float32)
    it = jax.lax.broadcasted_iota(jnp.int32, (Lc, Lc), 0)
    is_ = jax.lax.broadcasted_iota(jnp.int32, (Lc, Lc), 1)
    mask = it >= is_
    # Mask the log-decay before exp (upper triangle is large-positive and
    # would overflow to inf, poisoning the masked product with NaN).
    ldiff = jnp.where(mask, cum - cum.reshape(1, Lc), -jnp.inf)
    m = scores * jnp.exp(ldiff) * dt.reshape(1, Lc)
    y_intra = jnp.dot(m, x, preferred_element_type=jnp.float32)

    o_ref[0, 0] = (y_inter + y_intra).astype(o_ref.dtype)

    # ---- state update: S = S_prev * exp(cum_L) + sum_s exp(cum_L - cum_s)
    #       * dt_s * B_s (x) x_s
    total = cum[Lc - 1]                          # scalar (1,)
    w = jnp.exp(total - cum) * dt                # (Lc, 1)
    state_ref[...] = s_prev * jnp.exp(total) + jnp.dot(
        (bmat * w).T, x, preferred_element_type=jnp.float32
    )


def ssd_scan(
    x: jax.Array,     # (B, L, H, Dh)
    dt: jax.Array,    # (B, L, H)   positive step sizes (post-softplus)
    a: jax.Array,     # (H,)        negative decay rates
    bmat: jax.Array,  # (B, L, H, N)
    cmat: jax.Array,  # (B, L, H, N)
    *,
    chunk: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Chunked SSD scan. Returns y: (B, L, H, Dh) (without the D*x skip)."""
    B, L, H, Dh = x.shape
    N = bmat.shape[-1]
    Lc = min(chunk, L)
    if L % Lc:
        raise ValueError(f"L={L} must be divisible by chunk={Lc}")
    n_chunks = L // Lc
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"

    # Layout: head-major so each (b, h) scans its own sequence.
    xh = x.transpose(0, 2, 1, 3)        # (B, H, L, Dh)
    dth = dt.transpose(0, 2, 1)[..., None]  # (B, H, L, 1)
    bh = bmat.transpose(0, 2, 1, 3)     # (B, H, L, N)
    ch = cmat.transpose(0, 2, 1, 3)
    ah = a.reshape(H, 1, 1).astype(jnp.float32)  # (H, 1, 1)

    grid = (B, H, n_chunks)
    out = pl.pallas_call(
        functools.partial(_kernel, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Lc, Dh), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Lc, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, h, c: (h, 0, 0)),
            pl.BlockSpec((1, 1, Lc, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Lc, N), lambda b, h, c: (b, h, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Lc, Dh), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, L, Dh), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, Dh), jnp.float32)],
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(xh, dth, ah, bh, ch)
    return out.transpose(0, 2, 1, 3)    # (B, L, H, Dh)
