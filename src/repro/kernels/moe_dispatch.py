"""Megakernel-style MoE dispatch as a Pallas TPU remote-DMA kernel.

This is the paper's mechanism adapted to TPU (DESIGN.md §2).  Each EP rank
holds a send buffer ``buf[(P, e_local, C, H)]`` — one tile per (destination
rank, local-expert slot) — and the kernel delivers tile ``buf[dst, j]`` into
``out[src, j]`` on rank ``dst`` with one *async remote copy per expert tile*
(the paper's per-expert PUT granularity, §3.2).

Put-with-signal on TPU: ``pltpu.make_async_remote_copy`` increments the
*receiver's* DMA semaphore when the payload has landed — i.e. the signal is
hardware-coupled to the data, which is exactly the NIC-side ordering Perseus
argues for.  What the signaling schedule still controls on TPU is the
*sender-side issue discipline*:

  ``coupled``    — vanilla proxy semantics: the sender fully drains each
                   transfer (``wait_send``) before issuing the next one.
                   One serialized drain per expert tile — the analogue of
                   one proxy FENCE per PUT (Fig. 2a / Fig. 6a).
  ``decoupled``  — Perseus Algorithm 1: all tiles for one destination are
                   issued back-to-back, then one drain per destination
                   group before moving on (per-PE grouping, §4.1).
  ``perseus``    — all (P-1)*e_local tiles issued back-to-back with zero
                   intervening drains; a single terminal drain covers the
                   whole dispatch (decoupling + NIC-side ordering,
                   Fig. 2d).  ``nic_ordered`` is accepted as an alias: on
                   TPU the hardware recv semaphore *is* the NIC fence flag.

Receive side is schedule-independent: the rank waits on the per-source
recv semaphores (the "subscriber" of §2.3) and the tile is then ready for
expert compute.

NOTE — this is the *staged* path: the kernel drains **all** recv semaphores
before returning, so expert compute (a separate ``expert_gemm`` call)
cannot start until the last tile has landed, and the combine is a second
full dispatch after all compute retires.  That all-recv barrier is exactly
the hidden serialization the paper targets; ``fused_megakernel.py`` removes
it by folding per-tile expert compute and combine release into this kernel
(``backend="fused"``).  The staged path is kept for A/B benchmarking.

Communication kernels move HBM->HBM via the DMA engines, so refs live in
``pl.ANY`` memory space (no VMEM tiling — the compute kernels in
``expert_gemm.py``/``flash_attention.py`` own the VMEM BlockSpec story).
Correctness is validated in interpret mode (``pltpu.InterpretParams``),
which fully interprets cross-device DMAs on CPU; on real TPU the same code
lowers to Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

__all__ = ["remote_dispatch", "SCHEDULES"]

SCHEDULES = ("coupled", "decoupled", "nic_ordered", "perseus")


def _dispatch_kernel(
    buf_ref,          # (P, e_local, C, H) send tiles, ANY/HBM
    out_ref,          # (P, e_local, C, H) recv tiles, ANY/HBM
    local_sem,        # DMA sem for the self-block copy
    send_sems,        # (P, e_local) DMA sems, indexed [offset, expert]
    recv_sems,        # (P, e_local) DMA sems, indexed [offset, expert]
    *,
    num_ranks: int,
    e_local: int,
    axis_name: str,
    schedule: str,
):
    my_id = lax.axis_index(axis_name)

    # ---- self block: plain local DMA (NVLink/on-chip path, no proxy) ----
    local = pltpu.make_async_copy(
        buf_ref.at[my_id], out_ref.at[my_id], local_sem
    )
    local.start()

    def tile_copy(offset, j):
        """Remote copy of expert tile j to rank (me+offset); by symmetry the
        matching incoming tile arrives from rank (me-offset) on sem slot
        [offset, j]."""
        dst = lax.rem(my_id + offset, num_ranks)
        return pltpu.make_async_remote_copy(
            src_ref=buf_ref.at[dst, j],
            dst_ref=out_ref.at[my_id, j],
            send_sem=send_sems.at[offset, j],
            recv_sem=recv_sems.at[offset, j],
            device_id=dst,
            device_id_type=pltpu.DeviceIdType.LOGICAL,
        )

    # ---- sender-side issue discipline (the paper's schedules) -----------
    if schedule == "coupled":
        # PUT -> full drain -> (signal rides the drained DMA): serial issue.
        for offset in range(1, num_ranks):
            for j in range(e_local):
                c = tile_copy(offset, j)
                c.start()
                c.wait_send()          # proxy-FENCE analogue: drain per tile
    elif schedule == "decoupled":
        # Per-destination groups: burst the group's PUTs, one drain/group.
        for offset in range(1, num_ranks):
            group = [tile_copy(offset, j) for j in range(e_local)]
            for c in group:
                c.start()
            for c in group:
                c.wait_send()          # one batched drain per destination
    elif schedule in ("perseus", "nic_ordered"):
        # Everything in flight at once; ordering enforced by the hardware
        # recv semaphore (the "NIC fence flag" the TPU gives us for free).
        copies = [
            tile_copy(offset, j)
            for offset in range(1, num_ranks)
            for j in range(e_local)
        ]
        for c in copies:
            c.start()
        for c in copies:
            c.wait_send()              # terminal drain only
    else:  # pragma: no cover
        raise ValueError(f"unknown schedule {schedule!r}")

    # ---- receive side: subscriber waits per-source signals --------------
    for offset in range(1, num_ranks):
        for j in range(e_local):
            tile_copy(offset, j).wait_recv()
    local.wait()


@functools.partial(
    jax.named_call, name="moe_remote_dispatch"
)
def remote_dispatch(
    buf: jax.Array,
    *,
    axis_name: str,
    schedule: str = "perseus",
    interpret: bool | None = None,
) -> jax.Array:
    """ALLTOALL-equivalent remote dispatch with a Perseus signaling schedule.

    Args:
      buf: (P, e_local, C, H) per-rank send buffer; ``buf[dst]`` is the set
        of expert tiles destined for rank ``dst``.  Must be called inside
        ``shard_map`` over ``axis_name``.
      schedule: one of ``SCHEDULES``.
      interpret: force/disable interpret mode; default = interpret on CPU,
        compiled on TPU.

    Returns:
      (P, e_local, C, H): ``out[src]`` holds the tiles rank ``src`` sent us.
    """
    if schedule not in SCHEDULES:
        raise ValueError(f"schedule must be one of {SCHEDULES}, got {schedule}")
    num_ranks = compat.axis_size(axis_name)
    if buf.shape[0] != num_ranks:
        raise ValueError(
            f"buf leading dim {buf.shape[0]} != axis size {num_ranks}"
        )
    e_local = buf.shape[1]
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"

    kernel = functools.partial(
        _dispatch_kernel,
        num_ranks=num_ranks,
        e_local=e_local,
        axis_name=axis_name,
        schedule=schedule,
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(buf.shape, buf.dtype),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA((num_ranks, e_local)),
            pltpu.SemaphoreType.DMA((num_ranks, e_local)),
        ],
        interpret=compat.pallas_interpret(interpret),
        compiler_params=compat.tpu_compiler_params(
            has_side_effects=True,
            collective_id=7,
        ),
    )(buf)
