"""Grouped expert FFN (gated MLP) as a Pallas TPU kernel.

Computes, for every expert ``e`` over its capacity buffer::

    out[e] = (act(x[e] @ w1[e]) * (x[e] @ w3[e])) @ w2[e]

i.e. the paper's "two GEMMs and an activation" expert compute (§2.1), fused
so the (T, F) intermediate never round-trips through HBM.

Tiling: grid ``(E, T/bt, F/bf)`` with the F axis innermost.  Per grid step
the VMEM working set is::

    x   (bt, H)        activations for this token tile
    w1  (H, bf)        gate projection slice
    w3  (H, bf)        up projection slice
    w2  (bf, H)        down projection slice
    acc (bt, H) f32    output accumulator (scratch, persists across F steps)

With bt = bf = 128 and H up to ~8K this stays under ~8 MB of VMEM and all
matmul dims are MXU-aligned multiples of 128 for the full-size configs (the
kernel itself works for any shape; tests sweep small odd shapes in
interpret mode).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

__all__ = ["expert_ffn", "tile_ffn"]


def tile_ffn(x, w1, w3, w2, *, activation: str, f_start=0,
             f_total: int | None = None):
    """In-kernel gated-MLP body over one (token tile, F slice).

    The reusable compute core shared by this module's grid kernel and the
    fused dispatch+compute megakernel (``fused_megakernel.py``).  Operands
    are VMEM-resident arrays (NOT refs): ``x (bt, H)``, ``w1/w3 (H, bf)``,
    ``w2 (bf, H)``.  Returns the f32 ``(bt, H)`` partial sum contributed by
    this F slice; callers accumulate over slices (or pass the full F as one
    slice).

    ``f_total`` enables ragged-tail masking: when set, columns of the slice
    at global F index >= f_total are zeroed on *both* operands (padded
    w1/w3 columns and w2 rows hold garbage — NaN in interpret mode — and
    0*NaN = NaN would poison the reduction).
    """
    h1 = jnp.dot(x, w1, preferred_element_type=jnp.float32)
    h3 = jnp.dot(x, w3, preferred_element_type=jnp.float32)
    if activation == "silu":
        h = jax.nn.silu(h1) * h3
    elif activation == "gelu":
        h = jax.nn.gelu(h1) * h3
    else:
        raise ValueError(activation)
    if f_total is not None:
        col = f_start + jax.lax.broadcasted_iota(jnp.int32, h.shape, 1)
        h = jnp.where(col < f_total, h, 0.0)
        row = f_start + jax.lax.broadcasted_iota(jnp.int32, w2.shape, 0)
        w2 = jnp.where(row < f_total, w2, 0)
    return jnp.dot(
        h.astype(x.dtype), w2, preferred_element_type=jnp.float32
    )


def _kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref, acc_ref, *, n_f: int,
            f_total: int, activation: str):
    f = pl.program_id(2)

    @pl.when(f == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                   # (bt, H)
    bf = w1_ref.shape[-1]
    acc_ref[...] += tile_ffn(
        x, w1_ref[0], w3_ref[0], w2_ref[0], activation=activation,
        f_start=f * bf, f_total=f_total if f_total % bf else None,
    )

    @pl.when(f == n_f - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def expert_ffn(
    x: jax.Array,     # (E, T, H)
    w1: jax.Array,    # (E, H, F)
    w3: jax.Array,    # (E, H, F)
    w2: jax.Array,    # (E, F, H)
    *,
    activation: str = "silu",
    block_t: int = 128,
    block_f: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused grouped gated-MLP over stacked experts. Returns (E, T, H)."""
    E, T, H = x.shape
    F = w1.shape[-1]
    bt = min(block_t, T)
    bf = min(block_f, F)
    n_t = pl.cdiv(T, bt)
    n_f = pl.cdiv(F, bf)
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"

    grid = (E, n_t, n_f)
    return pl.pallas_call(
        functools.partial(_kernel, n_f=n_f, f_total=F, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, H), lambda e, t, f: (e, t, 0)),
            pl.BlockSpec((1, H, bf), lambda e, t, f: (e, 0, f)),
            pl.BlockSpec((1, H, bf), lambda e, t, f: (e, 0, f)),
            pl.BlockSpec((1, bf, H), lambda e, t, f: (e, f, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, H), lambda e, t, f: (e, t, 0)),
        out_shape=jax.ShapeDtypeStruct((E, T, H), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, H), jnp.float32)],
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(x, w1, w3, w2)
