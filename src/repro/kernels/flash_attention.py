"""Blockwise (flash) attention as a Pallas TPU kernel.

Online-softmax attention over KV blocks with GQA support: the kv-head block
index maps each query head to its shared KV head, so grouped KV is never
materialized per query head.

Tiling: grid ``(B, Hq, Tq/bq, Tk/bk)``, KV innermost.  VMEM per step::

    q   (bq, D)      k (bk, D)      v (bk, D)
    m, l (bq, 1) f32 running max / normalizer (scratch)
    acc (bq, D) f32  output accumulator (scratch)

Causal masking prunes fully-masked KV blocks via ``pl.when`` on the block
indices, giving the standard ~2x saving for long prefill.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

__all__ = ["flash_attention"]

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bk: int, n_k: int):
    tq = pl.program_id(2)
    tk = pl.program_id(3)

    @pl.when(tk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def body():
        q = q_ref[0, 0]                              # (bq, D)
        k = k_ref[0, 0]                              # (bk, D)
        v = v_ref[0, 0]                              # (bk, D)
        s = jnp.dot(
            q, k.T, preferred_element_type=jnp.float32
        ) * scale                                    # (bq, bk)
        if causal:
            iq = tq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            ik = tk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(iq >= ik, s, _NEG_INF)
        m_prev = m_ref[...]                          # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                       # (bq, bk)
        corr = jnp.exp(m_prev - m_new)               # (bq, 1)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    if causal:
        # Skip KV blocks entirely above the diagonal.
        @pl.when(tk * bk <= tq * bq + (bq - 1))
        def _():
            body()
    else:
        body()

    @pl.when(tk == n_k - 1)
    def _flush():
        l = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,      # (B, Hq, Tq, D)
    k: jax.Array,      # (B, Hkv, Tk, D)
    v: jax.Array,      # (B, Hkv, Tk, D)
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Flash attention with GQA. Returns (B, Hq, Tq, D)."""
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, Tq)
    bk = min(block_k, Tk)
    n_q = pl.cdiv(Tq, bq)
    n_k = pl.cdiv(Tk, bk)
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"

    grid = (B, Hq, n_q, n_k)
    return pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, bq=bq, bk=bk, n_k=n_k
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, tq, tk: (b, h, tq, 0)),
            pl.BlockSpec(
                (1, 1, bk, D),
                lambda b, h, tq, tk: (b, h // group, tk, 0),
            ),
            pl.BlockSpec(
                (1, 1, bk, D),
                lambda b, h, tq, tk: (b, h // group, tk, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bq, D), lambda b, h, tq, tk: (b, h, tq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=(
                "parallel", "parallel", "parallel", "arbitrary"
            ),
        ),
    )(q, k, v)
