"""JAX version-compatibility shims.

The codebase targets the current JAX API (``jax.shard_map``, ``jax.set_mesh``,
``lax.axis_size``, ``pltpu.CompilerParams``, ``pltpu.InterpretParams``); the
deployment image may carry an older release (0.4.x) where those spell
differently.  Every call site that straddles the divide goes through this
module so the version logic lives in exactly one place.

Covered:
  * ``shard_map``      — ``jax.shard_map(check_vma=...)`` vs
                         ``jax.experimental.shard_map.shard_map(check_rep=...)``
  * ``axis_size``      — ``lax.axis_size`` vs constant-folded ``psum(1, name)``
                         (both are *static* Python ints under shard_map tracing,
                         which the Pallas kernels rely on for loop bounds)
  * ``use_mesh``       — ``jax.set_mesh`` vs the ``Mesh`` context manager
  * ``tpu_compiler_params`` — ``pltpu.CompilerParams`` vs
                         ``pltpu.TPUCompilerParams`` (which has no
                         ``has_side_effects``; outputs keep DMA kernels alive)
  * ``pallas_interpret``    — ``pltpu.InterpretParams()`` vs legacy ``True``
"""

from __future__ import annotations

from typing import Any

import jax
from jax import lax
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "shard_map",
    "axis_size",
    "use_mesh",
    "tpu_compiler_params",
    "pallas_interpret",
]


def shard_map(f, *, mesh, in_specs, out_specs):
    """Per-shard map with replication checking off (collectives differ)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )


def axis_size(axis_name: str) -> int:
    """Static size of a mapped mesh axis (usable as a Python loop bound)."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    # Older JAX: psum of a Python literal is constant-folded to an int
    # during shard_map tracing.
    return lax.psum(1, axis_name)


def use_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh  # jax.sharding.Mesh is itself a context manager


def tpu_compiler_params(**kwargs) -> Any:
    """Build pltpu compiler params across the CompilerParams rename."""
    if hasattr(pltpu, "CompilerParams"):
        return pltpu.CompilerParams(**kwargs)
    # TPUCompilerParams has no has_side_effects; DMA kernels stay alive via
    # their (always-consumed) outputs.
    kwargs.pop("has_side_effects", None)
    return pltpu.TPUCompilerParams(**kwargs)


def pallas_interpret(enable: bool):
    """Value for ``pallas_call(interpret=...)`` that fully interprets on CPU
    (including cross-device DMAs) when ``enable`` is true."""
    if not enable:
        return False
    if hasattr(pltpu, "InterpretParams"):
        return pltpu.InterpretParams()
    return True
