"""Batched serving loop with continuous batching.

A fixed pool of decode *slots* (the batch dimension of the KV cache) is
kept full from a request queue: finished/empty slots are refilled by
prefilling the incoming prompt into that slot's cache rows (per-slot
prefill uses the decode path token-by-token for simplicity and exactness —
bulk prefill of a fresh batch uses the model's full-sequence prefill).

This is the serving analogue of the paper's inference workload: decode is
the overhead-dominated regime (small S) where Perseus's fence elimination
matters most (§8 "Prefill vs decode").
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import Model

__all__ = ["Request", "ServeConfig", "Server"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeConfig:
    slots: int = 4
    max_len: int = 256
    greedy: bool = True
    temperature: float = 1.0
    # MoE dispatch backend for decode steps: "gathered" on a single device;
    # under a mesh, "replicated" (psum layout), "collective" (ALLTOALL),
    # "megakernel" (staged Pallas dispatch) or "fused" (dispatch + expert
    # FFN + combine in one kernel — the overhead-dominated decode regime is
    # exactly where its tile-granular overlap matters, §8).
    moe_backend: str = "gathered"
    mesh: Any = None
    moe_token_axes: tuple[str, ...] = ("data", "model")


class Server:
    def __init__(self, model: Model, params, cfg: ServeConfig, *,
                 memory=None, seed: int = 0):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.memory = memory
        self.caches = model.init_caches(cfg.slots, cfg.max_len)
        self.pos = np.zeros(cfg.slots, dtype=np.int32)      # per-slot cursor
        self.active: list[Request | None] = [None] * cfg.slots
        self.pending: list[Request] = []
        self.finished: list[Request] = []
        self.rng = np.random.RandomState(seed)
        self._step = jax.jit(
            lambda p, t, c, pos: model.decode_step(
                p, t, c, pos, memory=memory,
                moe_backend=cfg.moe_backend, mesh=cfg.mesh,
                moe_token_axes=cfg.moe_token_axes,
            )
        )

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.pending.append(req)

    def _fill_slots(self):
        for s in range(self.cfg.slots):
            if self.active[s] is None and self.pending:
                req = self.pending.pop(0)
                self.active[s] = req
                # Feed the prompt through the decode path token by token
                # into this slot's cache rows (slot-local prefill).
                for t in req.prompt[:-1]:
                    self._advance_slot(s, t, record=False)
                # leave the last prompt token to produce the first output
                self._advance_slot(s, req.prompt[-1], record=True)

    def _advance_slot(self, s: int, token: int, *, record: bool):
        # Run a full-batch step but only slot s consumes a real token; other
        # slots feed their own last token (no-op for empty slots).  Cheap at
        # toy scale; a production engine would use per-slot position vectors.
        tokens = np.zeros((self.cfg.slots, 1), dtype=np.int32)
        tokens[s, 0] = token
        logits, self.caches = self._step(
            self.params, jnp.asarray(tokens), self.caches,
            jnp.int32(int(self.pos[s])),
        )
        self.pos[s] += 1
        if record:
            nxt = self._sample(np.asarray(logits[s]))
            req = self.active[s]
            req.out.append(int(nxt))

    def _sample(self, logits: np.ndarray) -> int:
        if self.cfg.greedy:
            return int(np.argmax(logits))
        p = np.exp(logits / self.cfg.temperature)
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    # ------------------------------------------------------------------
    def step(self):
        """One decode tick over all active slots (batched)."""
        self._fill_slots()
        live = [s for s in range(self.cfg.slots) if self.active[s]]
        if not live:
            return False
        tokens = np.zeros((self.cfg.slots, 1), dtype=np.int32)
        for s in live:
            tokens[s, 0] = self.active[s].out[-1]
        # All live slots share a position cursor in this simplified engine;
        # use the max (caches are slot-row independent for attention).
        pos = int(max(self.pos[s] for s in live))
        logits, self.caches = self._step(
            self.params, jnp.asarray(tokens), self.caches, jnp.int32(pos)
        )
        logits = np.asarray(logits)
        for s in live:
            req = self.active[s]
            req.out.append(self._sample(logits[s]))
            self.pos[s] += 1
            if (len(req.out) >= req.max_new_tokens
                    or self.pos[s] >= self.cfg.max_len - 1):
                req.done = True
                self.finished.append(req)
                self.active[s] = None
        return True

    def run_until_drained(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.pending or any(self.active)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished
