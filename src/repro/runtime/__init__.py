"""repro.runtime subsystem."""
