"""Elastic scaling: resume a run on a different device count / mesh shape.

Checkpoints store *global* (mesh-independent) arrays, so rescaling is a
restore with new shardings plus a data-cursor adjustment:

  * scale-down (lost nodes): restore onto the smaller mesh (each device
    holds a larger shard), keep the global batch by raising grad-accum;
  * scale-up: restore onto the larger mesh, lower grad-accum.

``plan_rescale`` computes the new (mesh, grad_accum, shardings) tuple;
``rescale_state`` materializes the restored state.  On a 1000+-node
deployment the same logic runs per-host against the sharded checkpoint
format (each host reads only its shard ranges — the manifest carries
global shapes, so the mapping is deterministic).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager

__all__ = ["RescalePlan", "plan_rescale", "rescale_state"]


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    old_data_parallel: int
    new_data_parallel: int
    grad_accum_multiplier: int     # keep the global batch constant
    mesh_axes: tuple[str, ...]

    @property
    def keeps_global_batch(self) -> bool:
        return (self.old_data_parallel % self.new_data_parallel == 0
                or self.new_data_parallel % self.old_data_parallel == 0)


def plan_rescale(old_dp: int, new_dp: int,
                 axes: tuple[str, ...] = ("data", "model")) -> RescalePlan:
    """Keep global batch fixed: grad-accum absorbs the DP-degree change."""
    if new_dp <= 0:
        raise ValueError("new data-parallel degree must be positive")
    mult = max(1, old_dp // new_dp)
    return RescalePlan(
        old_data_parallel=old_dp,
        new_data_parallel=new_dp,
        grad_accum_multiplier=mult,
        mesh_axes=axes,
    )


def rescale_state(
    ckpt: CheckpointManager,
    target_tree: Any,
    new_mesh: Mesh,
    specs: Any,
    *,
    step: int | None = None,
) -> tuple[Any, dict]:
    """Restore a checkpoint resharded for ``new_mesh``.

    ``specs`` is the PartitionSpec pytree for ``target_tree`` (same rules as
    training — e.g. ``parallel.sharding.param_specs``); arrays land directly
    with the new sharding, no host-side reassembly beyond the npz read.
    """
    shardings = jax.tree.map(
        lambda s: NamedSharding(new_mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    return ckpt.restore(target_tree, step=step, shardings=shardings)
