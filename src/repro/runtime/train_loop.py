"""Fault-tolerant training loop.

Production-shape concerns handled here (DESIGN.md §9):

  * checkpoint/restart — periodic async snapshots (params + opt state +
    data cursor); on *any* step failure the loop restores the latest
    snapshot and replays from there (at-least-once step semantics, data
    pipeline is counter-based so replays are deterministic);
  * straggler detection — per-step wall-time EWMA + z-score flagging with a
    pluggable response hook (the paper's fence-drain tail is exactly this
    failure mode at the transport layer);
  * fault injection — tests drive recovery through ``fault_hook``;
  * gradient accumulation — microbatched scan so XLA overlaps the DP
    all-reduce of microbatch i with compute of i+1.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.optim.adamw import OptConfig, OptState, apply_updates, init_opt

__all__ = ["TrainConfig", "StragglerMonitor", "Trainer", "make_train_step"]


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    grad_accum: int = 1
    log_every: int = 10
    max_restarts: int = 3


class StragglerMonitor:
    """EWMA + z-score step-time monitor (per-host in multi-host settings)."""

    def __init__(self, alpha: float = 0.1, z_threshold: float = 4.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.z = z_threshold
        self.warmup = warmup
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.n <= self.warmup:
            # prime the EWMA
            self.mean = dt if self.n == 1 else (
                self.mean + (dt - self.mean) / self.n
            )
            self.var = max(self.var, (dt - self.mean) ** 2)
            return False
        std = max(1e-9, self.var ** 0.5)
        is_straggler = (dt - self.mean) / std > self.z and dt > 1.5 * self.mean
        d = dt - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        if is_straggler:
            self.flagged.append((step, dt))
        return is_straggler


def make_train_step(
    loss_fn: Callable,          # (params, batch) -> scalar loss
    opt_cfg: OptConfig,
    *,
    grad_accum: int = 1,
    donate: bool = True,
    jit: bool = True,
):
    """Build the (jitted) train step: loss -> grads -> clip -> AdamW."""

    def step(params, opt_state: OptState, batch):
        if grad_accum > 1:
            # split batch on axis 0 into microbatches and scan-accumulate;
            # XLA overlaps each microbatch's grad all-reduce with the next
            # microbatch's compute.
            def micro(carry, mb):
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                acc_loss, acc_g = carry
                return (
                    acc_loss + loss,
                    jax.tree.map(jnp.add, acc_g, grads),
                ), None

            micro_batches = jax.tree.map(
                lambda x: x.reshape(
                    (grad_accum, x.shape[0] // grad_accum) + x.shape[1:]
                ),
                batch,
            )
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), zero), micro_batches
            )
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, metrics = apply_updates(
            params, grads, opt_state, opt_cfg
        )
        metrics["loss"] = loss
        return params, opt_state, metrics

    if not jit:
        return step
    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


class Trainer:
    """Drives the loop with checkpoint/restart + straggler monitoring."""

    def __init__(
        self,
        train_step: Callable,
        dataset,                      # SyntheticDataset-like: .batch(i)
        params,
        cfg: TrainConfig,
        *,
        fault_hook: Callable[[int], None] | None = None,
        log: Callable[[str], None] = print,
    ):
        self.train_step = train_step
        self.dataset = dataset
        self.cfg = cfg
        self.params = params
        self.opt_state = init_opt(params)
        self.step_idx = 0
        self.monitor = StragglerMonitor()
        self.ckpt = CheckpointManager(
            cfg.ckpt_dir, keep=cfg.keep, async_save=True
        )
        self.fault_hook = fault_hook
        self.log = log
        self.restarts = 0
        self.history: list[dict] = []

    # -- persistence ----------------------------------------------------
    def _state_tree(self):
        return {
            "params": self.params,
            "opt": self.opt_state._asdict(),
        }

    def save(self):
        self.ckpt.save(
            self.step_idx, self._state_tree(),
            metadata={"step_idx": self.step_idx},
        )

    def restore(self):
        tree, meta = self.ckpt.restore(self._state_tree())
        self.params = tree["params"]
        self.opt_state = OptState(**tree["opt"])
        self.step_idx = int(meta["step_idx"])
        self.log(f"[trainer] restored checkpoint at step {self.step_idx}")

    # -- main loop --------------------------------------------------------
    def run(self) -> list[dict]:
        while self.step_idx < self.cfg.steps:
            try:
                self._run_segment()
            except Exception as e:  # device loss / injected fault / NaN
                self.restarts += 1
                self.log(
                    f"[trainer] step {self.step_idx} failed ({e!r}); "
                    f"restart {self.restarts}/{self.cfg.max_restarts}"
                )
                if self.restarts > self.cfg.max_restarts:
                    raise
                if self.ckpt.latest_step() is None:
                    self.log("[trainer] no checkpoint yet; reinit from step 0")
                    self.step_idx = 0
                else:
                    self.restore()
        self.ckpt.wait()
        return self.history

    def _run_segment(self):
        while self.step_idx < self.cfg.steps:
            i = self.step_idx
            if self.fault_hook is not None:
                self.fault_hook(i)
            batch = self.dataset.batch(i)
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch
            )
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            if not jnp.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at step {i}")
            if self.monitor.observe(i, dt):
                self.log(
                    f"[trainer] straggler: step {i} took {dt*1e3:.1f}ms "
                    f"(ewma {self.monitor.mean*1e3:.1f}ms)"
                )
            self.history.append(
                {"step": i, "loss": loss, "time_s": dt,
                 "grad_norm": float(metrics["grad_norm"])}
            )
            if self.cfg.log_every and i % self.cfg.log_every == 0:
                self.log(f"[trainer] step {i} loss {loss:.4f} ({dt*1e3:.0f}ms)")
            self.step_idx = i + 1
            if self.cfg.ckpt_every and self.step_idx % self.cfg.ckpt_every == 0:
                self.save()
