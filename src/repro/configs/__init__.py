"""repro.configs subsystem."""
