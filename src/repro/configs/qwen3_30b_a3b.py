"""Paper Table 1: Qwen3-30B-A3B — the communication-bound MoE."""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen3-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    d_ff_expert=768,
    vocab=151936,
    n_experts=128,
    top_k=8,
    pattern=(LayerSpec(mixer="attn", ffn="moe"),),
)
