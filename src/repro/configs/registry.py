"""Architecture registry: ``--arch <id>`` resolution + shape assignment."""

from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, LM_SHAPES, ShapeSpec

__all__ = [
    "ASSIGNED", "PAPER_OWN", "ALL_ARCHS", "get_config", "shape_cells",
    "cell_supported",
]

# The 10 assigned architectures (system-prompt pool) — module name per id.
ASSIGNED = {
    "dbrx-132b": "dbrx_132b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "mamba2-780m": "mamba2_780m",
    "granite-8b": "granite_8b",
    "gemma3-27b": "gemma3_27b",
    "internlm2-20b": "internlm2_20b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "whisper-tiny": "whisper_tiny",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "llava-next-34b": "llava_next_34b",
}

# The paper's own evaluation models (Table 1).
PAPER_OWN = {
    "qwen3-30b-a3b": "qwen3_30b_a3b",
    "gpt-oss-120b": "gpt_oss_120b",
    "deepseek-v3": "deepseek_v3",
}

ALL_ARCHS = {**ASSIGNED, **PAPER_OWN}


def get_config(name: str) -> ArchConfig:
    try:
        mod = ALL_ARCHS[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(ALL_ARCHS)}"
        ) from None
    return importlib.import_module(f"repro.configs.{mod}").CONFIG


def cell_supported(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether an (arch x shape) cell runs, with the skip reason if not.

    Skips per the assignment: ``long_500k`` only for sub-quadratic archs;
    decode shapes skipped for encoder-only archs (none assigned here —
    whisper is enc-dec and *does* decode).
    """
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, (
            "pure full-attention arch: 500k-token decode requires "
            "sub-quadratic attention (DESIGN.md §6)"
        )
    return True, ""


def shape_cells(arch: str) -> list[tuple[ShapeSpec, bool, str]]:
    cfg = get_config(arch)
    out = []
    for shape in LM_SHAPES.values():
        ok, why = cell_supported(cfg, shape)
        out.append((shape, ok, why))
    return out
