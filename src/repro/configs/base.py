"""Architecture & shape configuration schema.

Every assigned architecture is one ``ArchConfig`` instance in
``repro/configs/<id>.py``; the model zoo (`repro.models`) builds the network
purely from this description, so adding an architecture never touches model
code.

Layer structure is described as a *pattern*: a short tuple of ``LayerSpec``
that repeats over the depth (period-1 for homogeneous stacks, e.g. 6 for
gemma3's 5 local : 1 global attention).  The decoder scans over pattern
periods with per-slot stacked parameters, which keeps lowering time and HLO
size O(period), not O(n_layers) — essential for the 512-device dry-run.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal, Sequence

import jax
import jax.numpy as jnp

__all__ = ["LayerSpec", "ArchConfig", "ShapeSpec", "LM_SHAPES"]

MixerKind = Literal["attn", "attn_local", "rglru", "ssd", "none"]
FFNKind = Literal["mlp", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer slot inside the repeating pattern."""

    mixer: MixerKind = "attn"
    ffn: FFNKind = "mlp"
    cross_attn: bool = False          # decoder cross-attends to encoder memory
    window: int = 0                   # sliding window for attn_local


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    head_dim: int | None = None       # default d_model // n_heads

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0              # per-expert intermediate (d_ff if 0)
    capacity_factor: float = 1.25

    # SSM (mamba2) / recurrent (RG-LRU)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2               # d_inner = expand * d_model
    conv_kernel: int = 4
    rglru_c: float = 8.0

    # encoder-decoder (whisper): encoder layers mirror decoder dims
    n_encoder_layers: int = 0

    # VLM stub: number of image tokens prepended (precomputed embeddings)
    n_image_tokens: int = 0

    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    tie_embeddings: bool = True

    # ---- performance levers (hillclimbed in EXPERIMENTS.md §Perf) ----
    attn_chunk: int = 0        # >0: blockwise online-softmax attention
    loss_chunk: int = 0        # >0: sequence-chunked xent (no full logits)
    param_dtype: str = "float32"   # "bfloat16": store params in bf16

    # Which technique applies (DESIGN.md §6): EP/megakernel only for MoE.
    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return all(s.mixer in ("rglru", "ssd", "none") for s in self.pattern)

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k: no full-attention layer... except we allow
        patterns whose only global attention is a bounded fraction with
        decode-linear cost (gemma3).  Pure full-attention archs return False.
        """
        kinds = {s.mixer for s in self.pattern}
        if kinds <= {"rglru", "ssd", "none", "attn_local"}:
            return True
        # mixed local/global counts if local layers dominate (gemma3 5:1)
        n_global = sum(1 for s in self.pattern if s.mixer == "attn")
        return n_global * 2 < len(self.pattern)

    @property
    def hdim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def expert_ff(self) -> int:
        return self.d_ff_expert or self.d_ff

    @property
    def jdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    def n_periods(self) -> tuple[int, int]:
        """(full periods, remainder layers)."""
        p = len(self.pattern)
        return self.n_layers // p, self.n_layers % p

    def param_count(self) -> int:
        """Approximate total parameters (embeddings + per-layer)."""
        H, V = self.d_model, self.vocab
        total = V * H * (1 if self.tie_embeddings else 2)
        per_pattern = 0
        for s in self.pattern:
            if s.mixer in ("attn", "attn_local"):
                hd = self.hdim
                per_pattern += H * (self.n_heads * hd) + 2 * H * (
                    self.n_kv_heads * hd
                ) + (self.n_heads * hd) * H
            elif s.mixer == "rglru":
                d = self.d_ff // 2 if False else H
                per_pattern += 2 * H * H + 2 * H * self.conv_kernel + 2 * H
            elif s.mixer == "ssd":
                dh, N = self.ssm_head_dim, self.ssm_state
                inner = self.ssm_expand * H
                nh = max(1, inner // dh)
                per_pattern += (
                    H * (2 * inner + 2 * N + nh)    # in_x/in_z/B/C/dt
                    + inner * H                      # out proj
                    + self.conv_kernel * inner       # depthwise conv
                )
            if s.cross_attn:
                hd = self.hdim
                per_pattern += 2 * H * (self.n_heads * hd) + 2 * H * (
                    self.n_kv_heads * hd
                )
            if s.ffn == "mlp":
                per_pattern += 3 * H * self.d_ff
            elif s.ffn == "moe":
                per_pattern += self.n_experts * 3 * H * self.expert_ff + (
                    H * self.n_experts
                )
        total += per_pattern * self.n_layers / len(self.pattern)
        total += (self.n_encoder_layers) * (
            4 * H * H + 3 * H * self.d_ff
        )
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of E experts)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        moe_layers = sum(1 for s in self.pattern if s.ffn == "moe")
        moe_total = (
            self.n_experts * 3 * self.d_model * self.expert_ff
            * moe_layers * self.n_layers // len(self.pattern)
        )
        moe_active = moe_total * self.top_k // self.n_experts
        return int(full - moe_total + moe_active)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


LM_SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def reduce_for_smoke(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests (one step, no NaNs)."""
    period = len(cfg.pattern)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=max(period, min(2 * period, cfg.n_layers)),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) or 1,
        d_ff=128,
        d_ff_expert=64 if cfg.is_moe else 0,
        vocab=256,
        n_experts=min(cfg.n_experts, 8),
        top_k=min(cfg.top_k, 2),
        # drop-free capacity so dense/gathered/EP/decode agree bit-for-bit
        capacity_factor=8.0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else cfg.ssm_head_dim,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        n_image_tokens=min(cfg.n_image_tokens, 16),
        head_dim=16,
        pattern=tuple(
            dataclasses.replace(s, window=min(s.window, 32) if s.window else 0)
            for s in cfg.pattern
        ),
        dtype="float32",
    )
