"""whisper-tiny: enc-dec audio backbone; conv frontend is a STUB — the
driver feeds precomputed frame embeddings (arXiv:2212.04356)."""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,              # decoder layers
    n_encoder_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    pattern=(LayerSpec(mixer="attn", ffn="mlp", cross_attn=True),),
    tie_embeddings=True,
)
