"""recurrentgemma-2b: RG-LRU + local attention, 1 attn : 2 recurrent
(arXiv:2402.19427)."""
from repro.configs.base import ArchConfig, LayerSpec

_REC = LayerSpec(mixer="rglru", ffn="mlp")
_LOCAL = LayerSpec(mixer="attn_local", ffn="mlp", window=2048)

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    pattern=(_REC, _REC, _LOCAL),
)
