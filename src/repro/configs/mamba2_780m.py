"""mamba2-780m: attention-free SSD (state-space duality), arXiv:2405.21060."""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=24,          # nominal (attention-free)
    n_kv_heads=24,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    pattern=(LayerSpec(mixer="ssd", ffn="none"),),
)
