"""llava-next-34b: VLM; anyres vision tiling is a STUB — the driver feeds
precomputed patch embeddings as a prefix (hf:llava-hf/llava-v1.6)."""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    n_image_tokens=2880,     # anyres: base 576 + 4 tiles x 576
    pattern=(LayerSpec(mixer="attn", ffn="mlp"),),
)
