"""Paper Table 1: DeepSeek-V3 — 256 experts top-8."""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="deepseek-v3",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,
    d_ff_expert=2048,
    vocab=129280,
    n_experts=256,
    top_k=8,
    pattern=(LayerSpec(mixer="attn", ffn="moe"),),
)
