"""gemma3-27b: 5:1 local:global attention, 128k context (hf:google/gemma-3)."""
from repro.configs.base import ArchConfig, LayerSpec

_LOCAL = LayerSpec(mixer="attn_local", ffn="mlp", window=1024)
_GLOBAL = LayerSpec(mixer="attn", ffn="mlp")

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab=262144,
    pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    rope_theta=1e6,
)
