"""Paper Table 1: GPT-OSS-120B — the balanced MoE."""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="gpt-oss-120b",
    family="moe",
    n_layers=36,
    d_model=2880,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2880,
    d_ff_expert=2880,
    vocab=201088,
    n_experts=128,
    top_k=4,
    pattern=(LayerSpec(mixer="attn", ffn="moe"),),
)
