"""repro.data subsystem."""
