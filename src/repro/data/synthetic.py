"""Deterministic synthetic data pipeline.

Produces reproducible LM batches (and modality-stub embeddings) from a
seeded counter-based generator: batch ``i`` is a pure function of
``(seed, i)``, so restarts resume mid-epoch exactly (checkpoint stores only
the step counter), and every host materializes only its own shard.

The token stream is Markov-ish — each document samples a sparse transition
table — so models have signal to fit in integration tests (loss decreases),
unlike iid-uniform tokens.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec

__all__ = ["DataConfig", "SyntheticDataset", "make_batch_struct"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab: int = 256
    seq_len: int = 128
    global_batch: int = 8
    n_doc_states: int = 16     # Markov states per document


class SyntheticDataset:
    """Stateless batch generator: ``batch(i)`` is pure in (seed, i)."""

    def __init__(self, cfg: ArchConfig, shape: ShapeSpec, seed: int = 0,
                 *, batch_override: int | None = None,
                 seq_override: int | None = None):
        self.cfg = cfg
        self.seq = seq_override or shape.seq_len
        self.batch_size = batch_override or shape.global_batch
        self.seed = seed

    def batch(self, i: int) -> dict:
        cfg = self.cfg
        rng = np.random.RandomState((self.seed * 1_000_003 + i) % 2**31)
        # Markov chain per row: sparse transitions => learnable structure.
        V = cfg.vocab
        k = min(8, V)
        trans = rng.randint(0, V, size=(V, k))
        toks = np.empty((self.batch_size, self.seq + 1), dtype=np.int32)
        toks[:, 0] = rng.randint(0, V, size=self.batch_size)
        choices = rng.randint(0, k, size=(self.batch_size, self.seq))
        for t in range(self.seq):
            toks[:, t + 1] = trans[toks[:, t], choices[:, t]]
        out = {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }
        if cfg.family == "audio":
            out["frames"] = jnp.asarray(
                rng.randn(self.batch_size, self.seq, cfg.d_model)
                .astype(np.float32) * 0.1
            )
        if cfg.family == "vlm":
            out["img_embeds"] = jnp.asarray(
                rng.randn(self.batch_size, cfg.n_image_tokens, cfg.d_model)
                .astype(np.float32) * 0.1
            )
        return out

    def __iter__(self) -> Iterator[dict]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1


def make_batch_struct(cfg: ArchConfig, shape: ShapeSpec,
                      dtype=jnp.int32) -> dict:
    """ShapeDtypeStruct stand-ins for one batch (dry-run input_specs)."""
    B, T = shape.global_batch, shape.seq_len
    out = {}
    if shape.kind in ("train", "prefill"):
        out["tokens"] = jax.ShapeDtypeStruct((B, T), jnp.int32)
        out["labels"] = jax.ShapeDtypeStruct((B, T), jnp.int32)
        if cfg.family == "audio":
            out["frames"] = jax.ShapeDtypeStruct(
                (B, T, cfg.d_model), jnp.bfloat16
            )
        if cfg.family == "vlm":
            out["img_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16
            )
    else:
        out["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return out
