"""Fault-tolerant checkpointing: atomic, async, keep-k, elastic restore.

Layout::

    <dir>/step_000123/
        manifest.json        # tree structure, shapes, dtypes, metadata
        arrays.npz           # flattened leaves (host-local view)
    <dir>/LATEST             # atomic pointer file

Guarantees:
  * atomicity — writes go to ``step_N.tmp`` and are renamed only after
    fsync, so a crash mid-save never corrupts the restore point;
  * async — ``save`` can offload serialization to a worker thread
    (``wait()`` joins before the next save or exit);
  * keep-k GC — old steps beyond ``keep`` are removed after a successful
    save;
  * elastic restore — leaves are stored with *global* shapes and restored
    via ``jax.device_put`` against whatever sharding the new mesh
    prescribes, so the same checkpoint resumes on a different DP degree
    (scale-up/scale-down) or a different mesh shape.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import queue
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(_k(k) for k in kp) for kp, _ in flat]
    leaves = [v for _, v in flat]
    return paths, leaves, treedef


def _k(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._q: queue.Queue = queue.Queue()
        self._worker: threading.Thread | None = None
        self._error: BaseException | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, *, metadata: dict | None = None):
        """Snapshot ``tree`` at ``step``. Host-syncs the arrays, then
        serializes (optionally on a worker thread)."""
        paths, leaves, _ = _flatten_with_paths(tree)
        arrays = [np.asarray(x) for x in leaves]   # host sync
        meta = {
            "step": step,
            "paths": paths,
            "shapes": [list(a.shape) for a in arrays],
            "dtypes": [str(a.dtype) for a in arrays],
            "metadata": metadata or {},
        }
        if self.async_save:
            self.wait()
            self._worker = threading.Thread(
                target=self._write, args=(step, meta, arrays), daemon=True
            )
            self._worker.start()
        else:
            self._write(step, meta, arrays)

    def wait(self):
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, meta: dict, arrays: list[np.ndarray]):
        try:
            final = os.path.join(self.dir, f"step_{step:09d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(
                os.path.join(tmp, "arrays.npz"),
                **{f"a{i}": a for i, a in enumerate(arrays)},
            )
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            with open(os.path.join(self.dir, "LATEST.tmp"), "w") as f:
                f.write(str(step))
                f.flush()
                os.fsync(f.fileno())
            os.replace(
                os.path.join(self.dir, "LATEST.tmp"),
                os.path.join(self.dir, "LATEST"),
            )
            self._gc()
        except BaseException as e:   # surfaced on next wait()
            self._error = e

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(
                os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True
            )

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        path = os.path.join(self.dir, "LATEST")
        if os.path.exists(path):
            with open(path) as f:
                s = int(f.read().strip())
            if os.path.isdir(os.path.join(self.dir, f"step_{s:09d}")):
                return s
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target_tree: Any, step: int | None = None,
                *, shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``target_tree``.

        ``shardings`` (optional pytree of NamedSharding) reshards each leaf
        for the *current* mesh — this is the elastic-scaling path: global
        shapes in the checkpoint are mesh-independent.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        arrays = [data[f"a{i}"] for i in range(len(meta["paths"]))]

        paths, leaves, treedef = _flatten_with_paths(target_tree)
        by_path = dict(zip(meta["paths"], arrays))
        restored = []
        flat_sh = (jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))
            if shardings is not None else [None] * len(paths))
        for p, ref, sh in zip(paths, leaves, flat_sh):
            if p not in by_path:
                raise KeyError(f"checkpoint missing leaf {p}")
            a = by_path[p]
            if list(a.shape) != list(ref.shape):
                raise ValueError(
                    f"shape mismatch for {p}: ckpt {a.shape} vs {ref.shape}"
                )
            a = a.astype(ref.dtype)
            restored.append(
                jax.device_put(a, sh) if sh is not None else jnp.asarray(a)
            )
        return treedef.unflatten(restored), meta["metadata"]
