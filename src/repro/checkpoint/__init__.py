"""repro.checkpoint subsystem."""
