import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run profiler: inspect a compiled cell's HLO without hardware.

This is the "profile" of the perf loop (DESIGN.md §Perf hints): with no
wall-clock trace available, the evidence is the lowered IR — biggest
tensors (VMEM/HBM pressure, f32 round-trips), the collective schedule, and
op-class histograms.  The §Perf iterations in EXPERIMENTS.md were driven
by exactly these views (e.g. the f32 convert/slice round-trips of stacked
expert weights, and GSPMD's involuntary cache replication).

Usage::

    PYTHONPATH=src python -m repro.launch.profile --arch dbrx-132b \
        --shape decode_32k --tag perf --top 15
"""

import argparse
import dataclasses
import re
import sys
from collections import Counter

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4, "s16": 2,
    "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
}

_TENSOR_RE = re.compile(r"(f64|f32|bf16|f16|s32|s8|u32|u8|pred)\[([\d,]+)\]")
_OP_RE = re.compile(r"=\s*\w+\[[\d,]*\][^ ]*\s+([a-z][\w-]*)\(")


def tensor_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def top_tensors(hlo: str, top: int = 12, min_mb: float = 32.0):
    sizes: Counter = Counter()
    for m in _TENSOR_RE.finditer(hlo):
        b = tensor_bytes(m.group(1), m.group(2))
        if b >= min_mb * 1e6:
            sizes[f"{m.group(1)}[{m.group(2)}]"] += 1
    rows = sorted(
        ((tensor_bytes(*k.replace("]", "").split("[")), cnt, k)
         for k, cnt in sizes.items()),
        reverse=True,
    )
    return rows[:top]


def op_histogram(hlo: str, top: int = 12):
    ops: Counter = Counter()
    for m in _OP_RE.finditer(hlo):
        ops[m.group(1)] += 1
    return ops.most_common(top)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--layers", type=int, default=2,
                    help="depth to lower (small = readable HLO)")
    ap.add_argument("--top", type=int, default=12)
    ap.add_argument("--min-mb", type=float, default=32.0)
    args = ap.parse_args(argv)

    from repro.configs.base import LM_SHAPES
    from repro.configs.registry import get_config
    from repro.launch import dryrun
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(args.arch)
    cfg, quant = dryrun.apply_variant(cfg, args.tag)
    period = len(cfg.pattern)
    cfg = dataclasses.replace(
        cfg, n_layers=max(period, args.layers * period)
    )
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    _, compiled = dryrun._lower_compile(
        cfg, LM_SHAPES[args.shape], mesh, "collective", quant_opt=quant
    )
    hlo = compiled.as_text()
    mem = compiled.memory_analysis()

    print(f"# {args.arch} x {args.shape} x {args.mesh}"
          f"{' x ' + args.tag if args.tag else ''} "
          f"(lowered at {cfg.n_layers} layers)")
    print(f"memory: arg={mem.argument_size_in_bytes/1e9:.2f}GB "
          f"temp={mem.temp_size_in_bytes/1e9:.2f}GB "
          f"out={mem.output_size_in_bytes/1e9:.2f}GB")
    print(f"\n## top tensors (>= {args.min_mb:.0f} MB)")
    for b, cnt, k in top_tensors(hlo, args.top, args.min_mb):
        print(f"  {b/1e6:9.1f} MB x{cnt:<3d} {k}")
    print("\n## collective schedule")
    coll = dryrun.parse_collectives(hlo)
    for kind, cnt in sorted(coll["by_kind_count"].items()):
        by = coll["by_kind_bytes"].get(kind, 0.0)
        print(f"  {kind:20s} x{cnt:<4d} {by/1e9:9.3f} GB wire/device")
    print("\n## op histogram")
    for op, cnt in op_histogram(hlo, args.top):
        print(f"  {op:24s} x{cnt}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
