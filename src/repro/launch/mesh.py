"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run must set
``XLA_FLAGS`` before any jax initialization.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "mesh_axes_of", "SINGLE_POD", "MULTI_POD"]

SINGLE_POD = (16, 16)              # 256 chips
MULTI_POD = (2, 16, 16)            # 2 pods x 256 chips = 512


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axes_of(mesh) -> dict:
    """Axis-name bundle used by sharding rules."""
    names = mesh.axis_names
    data_axes = tuple(a for a in names if a in ("pod", "data"))
    return {
        "data_axes": data_axes,
        "model_axis": "model",
        "token_axes": data_axes + ("model",),
        "n_chips": int(mesh.size),
    }
