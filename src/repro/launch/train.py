"""Training launcher.

Runs a real training loop on the local device(s): reduced configs train on
CPU for integration testing / examples; the identical code path drives TPU
slices (the mesh and shardings come from the same ``parallel.sharding``
rules the dry-run validates at 256/512 chips).

Usage::

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import json
import os

import jax

from repro.configs.base import LM_SHAPES, reduce_for_smoke
from repro.configs.registry import get_config
from repro.data.synthetic import SyntheticDataset
from repro.models.registry import build_model
from repro.optim.adamw import OptConfig
from repro.runtime.train_loop import TrainConfig, Trainer, make_train_step


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=list(LM_SHAPES))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n_params/1e6:.2f}M params, "
          f"{len(jax.devices())} device(s)")

    ds = SyntheticDataset(
        cfg, LM_SHAPES[args.shape], seed=args.seed,
        batch_override=args.batch, seq_override=args.seq,
    )
    step = make_train_step(
        model.loss,
        OptConfig(lr=args.lr, warmup_steps=max(2, args.steps // 20),
                  total_steps=args.steps),
        grad_accum=args.grad_accum,
    )
    trainer = Trainer(
        step, ds, params,
        TrainConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                    ckpt_dir=args.ckpt_dir, log_every=10),
    )
    if args.resume and trainer.ckpt.latest_step() is not None:
        trainer.restore()
    history = trainer.run()
    first = sum(h["loss"] for h in history[:5]) / max(1, len(history[:5]))
    last = sum(h["loss"] for h in history[-5:]) / max(1, len(history[-5:]))
    print(f"[train] done: loss {first:.4f} -> {last:.4f} over "
          f"{len(history)} steps; stragglers={len(trainer.monitor.flagged)}")
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(history, f)


if __name__ == "__main__":
    main()
