"""repro.launch subsystem."""
