import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: parameters,
optimizer state, batches and KV caches are ``ShapeDtypeStruct`` stand-ins
(zero allocation), sharded over the production mesh; ``.lower().compile()``
must succeed and yields

  * ``memory_analysis``  — per-device bytes (fits / doesn't fit),
  * ``cost_analysis``    — HLO FLOPs / bytes for the roofline (§Roofline),
  * the collective schedule — parsed from the optimized HLO to get
    per-collective wire bytes (not available in cost_analysis).

Results are cached as JSON per cell (``results/dryrun/<arch>__<shape>__
<mesh>.json``) so reruns are incremental.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import ArchConfig, LM_SHAPES, ShapeSpec
from repro.configs.registry import ASSIGNED, ALL_ARCHS, cell_supported, get_config
from repro.data.synthetic import make_batch_struct
from repro.launch.mesh import make_production_mesh, mesh_axes_of
from repro.models import transformer as T
from repro.models.registry import build_model
from repro.optim.adamw import OptConfig, init_opt
from repro.parallel import sharding as shd
from repro.runtime.train_loop import make_train_step

RESULTS_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))),
    "results", "dryrun",
)

# -- collective parsing ------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\w+)\[([\d,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_OPERAND_RE = re.compile(r"\(\s*(\w+)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str, default_group: int = 16) -> dict:
    """Sum per-device wire bytes for every collective in the optimized HLO.

    Ring-algorithm wire model per participating device:
      all-reduce        2 * B * (n-1)/n
      all-gather        B_out * (n-1)/n
      reduce-scatter    B_in * (n-1)/n
      all-to-all        B * (n-1)/n
      collective-permute B
    """
    per_kind_bytes: dict[str, float] = {}
    per_kind_count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        out_bytes = _shape_bytes(dtype, dims)
        om = _OPERAND_RE.search(line[m.end() - 1:])
        in_bytes = _shape_bytes(om.group(1), om.group(2)) if om else out_bytes
        gi = _GROUPS_IOTA_RE.search(line)
        if gi:
            n = int(gi.group(2))
        else:
            gb = _GROUPS_BRACE_RE.search(line)
            n = len(gb.group(1).split(",")) if gb else default_group
        n = max(2, n)
        f = (n - 1) / n
        if kind == "all-reduce":
            wire = 2.0 * out_bytes * f
        elif kind == "all-gather":
            wire = out_bytes * f
        elif kind == "reduce-scatter":
            wire = in_bytes * f
        elif kind == "all-to-all":
            wire = out_bytes * f
        else:  # collective-permute
            wire = out_bytes
        per_kind_bytes[kind] = per_kind_bytes.get(kind, 0.0) + wire
        per_kind_count[kind] = per_kind_count.get(kind, 0) + 1
    return {
        "wire_bytes_per_device": sum(per_kind_bytes.values()),
        "by_kind_bytes": per_kind_bytes,
        "by_kind_count": per_kind_count,
    }


# -- step builders ------------------------------------------------------------


def _struct_params(cfg: ArchConfig):
    return jax.eval_shape(
        lambda k: T.init(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )


# --tag variants: perf-iteration levers applied on top of the baseline.
VARIANTS: dict[str, dict] = {
    "": {},
    "v2": {},                      # improved decode/serve sharding (code-level)
    "flashattn": {"attn_chunk": 512},
    "chunkloss": {"loss_chunk": 256},
    "bf16": {"param_dtype": "bfloat16"},
    "opt8": {"_quant_opt": True},
    "nocap": {"capacity_factor": 1.0},
    "perf": {"attn_chunk": 512, "loss_chunk": 256,
             "param_dtype": "bfloat16", "_quant_opt": True},
    "perf_nocap": {"attn_chunk": 512, "loss_chunk": 256,
                   "param_dtype": "bfloat16", "_quant_opt": True,
                   "capacity_factor": 1.0},
}


def apply_variant(cfg: ArchConfig, tag: str) -> tuple[ArchConfig, bool]:
    opts = dict(VARIANTS.get(tag, {}))
    quant = opts.pop("_quant_opt", False)
    if opts:
        cfg = dataclasses.replace(cfg, **opts)
    return cfg, quant


def build_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, *,
               moe_train_backend: str = "collective",
               quant_opt: bool = False):
    """Returns (fn, arg_structs, in_shardings) for this cell."""
    ax_info = mesh_axes_of(mesh)
    data_size = 1
    for a in ax_info["data_axes"]:
        data_size *= mesh.shape[a]
    axes = shd.MeshAxes(
        data=ax_info["data_axes"],
        data_size=data_size,
        model_size=mesh.shape["model"],
    )
    token_axes = ax_info["token_axes"]
    model = build_model(cfg)
    params_s = _struct_params(cfg)
    if cfg.param_dtype == "bfloat16":
        params_s = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
            if s.ndim >= 2 else s, params_s,
        )
    # FSDP only pays off when every step touches all shards (training);
    # serving keeps params TP/EP-sharded to avoid per-token all-gathers.
    fsdp = cfg.is_moe and shape.kind == "train"
    pspecs = shd.param_specs(params_s, cfg, axes, fsdp=fsdp)
    psh = shd.shardings_for(mesh, pspecs)
    batch_s = make_batch_struct(cfg, shape)
    bspecs = shd.batch_specs(cfg, shape, axes)
    bsh = {k: NamedSharding(mesh, bspecs[k]) for k in batch_s}

    if shape.kind == "train":
        opt_s = jax.eval_shape(
            lambda p: init_opt(p, quantize=quant_opt), params_s
        )
        mu_specs = shd.param_specs(opt_s.mu, cfg, axes, fsdp=fsdp)
        osh = type(opt_s)(
            mu=shd.shardings_for(mesh, mu_specs),
            nu=shd.shardings_for(mesh, mu_specs),
            step=NamedSharding(mesh, P()),
        )
        backend = moe_train_backend if cfg.is_moe else "gathered"
        fn = make_train_step(
            lambda p, b: model.loss(
                p, b, moe_backend=backend, mesh=mesh,
                moe_token_axes=token_axes,
            ),
            OptConfig(),
            donate=False,
            jit=False,
        )
        args = (params_s, opt_s, batch_s)
        in_sh = (psh, osh, bsh)
        donate = ()

    elif shape.kind == "prefill":
        backend = moe_train_backend if cfg.is_moe else "gathered"

        def fn(params, batch):
            logits, caches, _mem = model.prefill(
                params, batch, moe_backend=backend, mesh=mesh,
                moe_token_axes=token_axes,
            )
            return logits, caches

        args = (params_s, batch_s)
        in_sh = (psh, bsh)
        donate = ()

    else:  # decode
        B = shape.global_batch
        caches_s = jax.eval_shape(
            lambda: T.init_caches(cfg, B, shape.seq_len, cfg.jdtype)
        )
        cspecs = shd.cache_specs(cfg, shape, caches_s, axes)
        csh = shd.shardings_for(mesh, cspecs)
        backend = "replicated" if cfg.is_moe else "gathered"
        dp_axes = tuple(a for a in token_axes if a != "model")
        moe_axes = (dp_axes + ("model",)) if B >= 16 else ("model",)
        tokens_s = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos_s = jax.ShapeDtypeStruct((), jnp.int32)

        if cfg.family == "audio":
            # enc-dec decode cross-attends to the (stub) encoder memory.
            mem_s = jax.ShapeDtypeStruct(
                (B, shape.seq_len, cfg.d_model), cfg.jdtype
            )
            b_ax = bspecs["tokens"][0]

            def fn(params, tokens, caches, pos, memory):
                return model.decode_step(
                    params, tokens, caches, pos, memory=memory,
                    moe_backend=backend, mesh=mesh, moe_token_axes=moe_axes,
                )

            donate = (2,)
            args = (params_s, tokens_s, caches_s, pos_s, mem_s)
            in_sh = (
                psh,
                NamedSharding(mesh, bspecs["tokens"]),
                csh,
                NamedSharding(mesh, P()),
                NamedSharding(mesh, P(b_ax, None, None)),
            )
            return fn, args, in_sh, donate

        def fn(params, tokens, caches, pos):
            return model.decode_step(
                params, tokens, caches, pos,
                moe_backend=backend, mesh=mesh, moe_token_axes=moe_axes,
            )

        donate = (2,)
        args = (params_s, tokens_s, caches_s, pos_s)
        in_sh = (
            psh,
            NamedSharding(mesh, bspecs["tokens"]),
            csh,
            NamedSharding(mesh, P()),
        )
    return fn, args, in_sh, donate


def _analyze(compiled) -> dict:
    out = {}
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        out["flops"] = float(ca.get("flops", 0.0))
        out["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
    except Exception as e:
        out["error"] = repr(e)
    try:
        out["collectives"] = parse_collectives(compiled.as_text())
    except Exception as e:
        out["collectives"] = {"error": repr(e)}
    return out


def _lower_compile(cfg, shape, mesh, moe_train_backend, *,
                   quant_opt: bool = False):
    fn, args, in_sh, donate = build_cell(
        cfg, shape, mesh, moe_train_backend=moe_train_backend,
        quant_opt=quant_opt,
    )
    with compat.use_mesh(mesh):
        lowered = jax.jit(
            fn, in_shardings=in_sh, donate_argnums=donate
        ).lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


def extrapolate_roofline(cfg: ArchConfig, shape: ShapeSpec, mesh,
                         moe_train_backend: str,
                         quant_opt: bool = False) -> dict:
    """Two-point depth extrapolation for loop-undercounted cost analysis.

    XLA's cost_analysis counts a while-loop body once regardless of trip
    count, so the scan-over-periods program under-reports FLOPs / bytes /
    collective traffic.  Lowering the same cell at depth = 1 and 2 pattern
    periods gives F1 (fixed costs + one period) and F2 - F1 (exactly one
    period, fwd+bwd+optimizer); the true totals are
    ``F1 + (F2-F1) * (n_periods - 1 + n_rem/period)``.
    """
    period = len(cfg.pattern)
    n_per, n_rem = cfg.n_periods()
    enc = cfg.n_encoder_layers

    def mini(n):
        c = dataclasses.replace(
            cfg, n_layers=n * period,
            n_encoder_layers=min(enc, max(1, n)) if enc else 0,
        )
        _, compiled = _lower_compile(c, shape, mesh, moe_train_backend,
                                     quant_opt=quant_opt)
        return _analyze(compiled)

    a1 = mini(1)
    a2 = mini(2)
    if "error" in a1 or "error" in a2:
        return {"error": a1.get("error") or a2.get("error")}
    mult = (n_per - 1) + (n_rem / period)
    if enc and enc > 2:
        # encoder layers scale alongside (same two-point slope)
        mult_note = "encoder folded into period slope"
    out = {
        "flops": a1["flops"] + (a2["flops"] - a1["flops"]) * mult,
        "bytes_accessed": a1["bytes_accessed"]
        + (a2["bytes_accessed"] - a1["bytes_accessed"]) * mult,
        "per_period_flops": a2["flops"] - a1["flops"],
        "fixed_flops": 2 * a1["flops"] - a2["flops"],
    }
    c1 = a1["collectives"].get("wire_bytes_per_device", 0.0)
    c2 = a2["collectives"].get("wire_bytes_per_device", 0.0)
    out["wire_bytes_per_device"] = c1 + (c2 - c1) * mult
    out["by_kind_bytes"] = {
        k: a1["collectives"]["by_kind_bytes"].get(k, 0.0)
        + (a2["collectives"]["by_kind_bytes"].get(k, 0.0)
           - a1["collectives"]["by_kind_bytes"].get(k, 0.0)) * mult
        for k in set(a1["collectives"]["by_kind_bytes"])
        | set(a2["collectives"]["by_kind_bytes"])
    }
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             force: bool = False, moe_train_backend: str = "collective",
             out_dir: str = RESULTS_DIR, tag: str = "") -> dict:
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    out_path = os.path.join(
        out_dir, f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
    )
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = get_config(arch)
    cfg, quant_opt = apply_variant(cfg, tag)
    shape = LM_SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "kind": shape.kind, "tag": tag,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if not ok:
        rec.update(status="SKIP", reason=why)
        _write(out_path, rec)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        lowered, compiled = _lower_compile(cfg, shape, mesh,
                                           moe_train_backend,
                                           quant_opt=quant_opt)
        t_lower = 0.0
        t_compile = time.time() - t0
        try:
            mem = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(
                    mem, "peak_memory_in_bytes",
                    getattr(mem, "temp_size_in_bytes", None),
                ),
            }
        except Exception as e:
            rec["memory"] = {"error": repr(e)}
        try:
            ca = compiled.cost_analysis()
            ca = ca[0] if isinstance(ca, (list, tuple)) else ca
            rec["cost"] = {
                "flops": float(ca.get("flops", 0.0)),
                "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
                "transcendentals": float(ca.get("transcendentals", 0.0)),
            }
        except Exception as e:
            rec["cost"] = {"error": repr(e)}
        try:
            hlo = compiled.as_text()
            rec["collectives"] = parse_collectives(hlo)
            rec["hlo_lines"] = hlo.count("\n")
        except Exception as e:
            rec["collectives"] = {"error": repr(e)}
        # Loop-aware roofline terms (scan bodies undercounted otherwise).
        rec["extrapolated"] = extrapolate_roofline(
            cfg, shape, mesh, moe_train_backend, quant_opt
        )
        rec.update(
            status="OK",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            n_devices=int(mesh.size),
        )
    except Exception as e:
        rec.update(
            status="FAIL",
            error=f"{type(e).__name__}: {e}",
            traceback=traceback.format_exc()[-4000:],
        )
    _write(out_path, rec)
    return rec


def _write(path: str, rec: dict):
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    os.replace(tmp, path)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(LM_SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="all assigned archs x shapes")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="variant tag for perf iters")
    ap.add_argument("--moe-backend", default="collective",
                    choices=["collective", "megakernel", "fused"])
    args = ap.parse_args(argv)

    archs = list(ASSIGNED) if args.all or not args.arch else [args.arch]
    shapes = list(LM_SHAPES) if args.all or not args.shape else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                rec = run_cell(
                    arch, shape, mk, force=args.force,
                    moe_train_backend=args.moe_backend, tag=args.tag,
                )
                status = rec["status"]
                extra = ""
                if status == "OK":
                    coll = rec.get("collectives", {})
                    extra = (
                        f" flops={rec['cost'].get('flops', 0):.3g}"
                        f" wireB={coll.get('wire_bytes_per_device', 0):.3g}"
                        f" compile={rec.get('compile_s')}s"
                    )
                elif status == "FAIL":
                    n_fail += 1
                    extra = " " + rec.get("error", "")[:160]
                elif status == "SKIP":
                    extra = " " + rec.get("reason", "")[:80]
                print(f"[dryrun] {arch:20s} {shape:12s} {mk:6s} {status}{extra}",
                      flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
