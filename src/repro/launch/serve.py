"""Serving launcher: batched decode with continuous batching.

Usage::

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --requests 6 --max-new 8
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs.base import reduce_for_smoke
from repro.configs.registry import get_config
from repro.models.registry import build_model
from repro.runtime.serve_loop import Request, ServeConfig, Server


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    if cfg.family in ("audio",):
        raise SystemExit("enc-dec serving demo: use examples/whisper_decode")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    srv = Server(model, params,
                 ServeConfig(slots=args.slots, max_len=args.max_len),
                 seed=args.seed)
    rng = jax.random.PRNGKey(args.seed + 1)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        rng, k = jax.random.split(rng)
        prompt = jax.random.randint(
            k, (4,), 0, cfg.vocab
        ).tolist()
        srv.submit(Request(rid=rid, prompt=prompt,
                           max_new_tokens=args.max_new))
    done = srv.run_until_drained()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"[serve] {cfg.name}: {len(done)} requests, "
          f"{total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  rid={r.rid} prompt={r.prompt} -> {r.out}")


if __name__ == "__main__":
    main()
